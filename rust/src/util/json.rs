//! Minimal JSON parser/writer.
//!
//! No `serde`/`serde_json` in the vendored crate set, so this module owns
//! the crate's structured-data interchange: the AOT `manifest.json` emitted
//! by `python/compile/aot.py`, experiment config files, and metric logs.
//! It implements the full JSON grammar (RFC 8259) minus surrogate-pair
//! escapes beyond the BMP, which we never emit.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic ordering.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|n| usize::try_from(n).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` when missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Build a `Json::Obj` from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a `Json::Arr` of numbers.
pub fn num_arr<I: IntoIterator<Item = f64>>(xs: I) -> Json {
    Json::Arr(xs.into_iter().map(Json::Num).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        // JSON has no NaN/Inf; emit null (matches python json.dumps(allow_nan=False) policy decisions upstream).
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("non-utf8 \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("surrogate \\u escape unsupported"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::parse(r#"{"k":[1,2,3],"s":"hi","n":-2.25}"#).unwrap();
        let v2 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,]", "{\"a\":}", "nul", "1.2.3", "\"abc", "[1] x"] {
            assert!(Json::parse(text).is_err(), "should reject {text:?}");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".to_string()));
    }

    #[test]
    fn integer_precision_preserved() {
        let v = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_f64(), Some(9007199254740992.0));
        let v = Json::parse("882").unwrap();
        assert_eq!(v.as_usize(), Some(882));
    }

    #[test]
    fn builders() {
        let v = obj(vec![("x", Json::Num(1.0)), ("y", num_arr([1.0, 2.0]))]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":[1,2]}"#);
    }
}
