//! Acceptance propchecks for the dynamic-graph delta subsystem
//! (`autogmap::delta`): random interleaved insert/delete/reweight/query
//! streams against flat and composite plans, 1/2/8 workers, both executor
//! modes, across at least one mid-stream remap — every served answer
//! bit-identical to a fresh host-CSR oracle of the mutated graph, and
//! post-remap serving bit-identical to a from-scratch deployment of the
//! same mutated matrix.
//!
//! All matrices, mutations, and query vectors are integer-valued, so every
//! f64 partial sum is exact and order-independent — comparisons are `==`,
//! never epsilon.

use autogmap::api::{DeploymentBuilder, Source, Strategy};
use autogmap::delta::{DeltaEngine, EdgeUpdate};
use autogmap::graph::{Coo, Csr};
use autogmap::util::pool::WorkerPool;
use autogmap::util::propcheck::check;
use autogmap::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Random symmetric integer-weight banded matrix (always a nonzero
/// diagonal, so RCM and the grid summary see every node).
fn integer_banded(rng: &mut Pcg64, dim: usize, band: usize) -> Csr {
    let mut coo = Coo::new(dim, dim);
    for i in 0..dim {
        coo.push(i, i, 1.0 + rng.below(4) as f64);
        for d in 1..=band {
            if i + d < dim && rng.below(3) > 0 {
                coo.push_sym(i, i + d, 1.0 + rng.below(4) as f64);
            }
        }
    }
    coo.to_csr()
}

/// The test's own mutable truth for the mutated graph, kept in *original*
/// node ids — deliberately independent of the engine's internal stores.
/// Snapshotting to a fresh `Csr` and running `spmv` is the "fresh
/// host-CSR oracle" the acceptance criteria name.
struct Oracle {
    rows: Vec<BTreeMap<usize, f64>>,
}

impl Oracle {
    fn from_csr(m: &Csr) -> Oracle {
        let mut rows = vec![BTreeMap::new(); m.rows];
        for (r, row) in rows.iter_mut().enumerate() {
            for (i, &c) in m.row(r).iter().enumerate() {
                row.insert(c, m.row_vals(r)[i]);
            }
        }
        Oracle { rows }
    }

    fn set(&mut self, r: usize, c: usize, w: f64) {
        if w == 0.0 {
            self.rows[r].remove(&c);
        } else {
            self.rows[r].insert(c, w);
        }
    }

    fn to_csr(&self) -> Csr {
        let n = self.rows.len();
        let mut coo = Coo::new(n, n);
        for (r, row) in self.rows.iter().enumerate() {
            for (&c, &v) in row {
                coo.push(r, c, v);
            }
        }
        coo.to_csr()
    }
}

fn deploy(
    m: Csr,
    strategy: Strategy,
    grid: usize,
    workers: usize,
) -> Result<autogmap::api::Deployment, String> {
    DeploymentBuilder::new(
        Source::Matrix { label: "delta-prop".into(), matrix: m },
        strategy,
    )
    .grid(grid)
    .banks(2)
    .workers(workers)
    .build()
    .map_err(|e| format!("deploy: {e:#}"))
}

/// One random mutation batch: inserts, reweights, and deletes (weight 0)
/// at uniform positions, all integer-valued.
fn random_updates(rng: &mut Pcg64, dim: usize, count: usize) -> Vec<EdgeUpdate> {
    (0..count)
        .map(|_| EdgeUpdate {
            row: rng.below(dim as u64) as usize,
            col: rng.below(dim as u64) as usize,
            weight: rng.below(5) as f64, // 0 deletes, 1..=4 insert/reweight
        })
        .collect()
}

fn integer_vec(rng: &mut Pcg64, dim: usize) -> Vec<f64> {
    (0..dim).map(|_| rng.below(9) as f64 - 4.0).collect()
}

/// Drive one interleaved update/query stream against a fresh engine:
/// every single and batched answer (in the given executor mode) must
/// equal a fresh host-CSR oracle of the mutated graph, before, across,
/// and after a mid-stream remap.
fn drive_stream(
    rng: &mut Pcg64,
    m: Csr,
    strategy: Strategy,
    grid: usize,
    workers: usize,
    sharded: bool,
) -> Result<(), String> {
    let dim = m.rows;
    let dep = deploy(m.clone(), strategy, grid, workers)?;
    let pool = Arc::new(WorkerPool::new(workers));
    let eng = DeltaEngine::attach(dep, pool).map_err(|e| format!("attach: {e}"))?;
    let mut oracle = Oracle::from_csr(&m);

    let steps = 6;
    let remap_at = 2 + rng.below(2) as usize;
    for step in 0..steps {
        let edges = random_updates(rng, dim, 1 + rng.below(6) as usize);
        let ack = eng
            .apply(&edges)
            .map_err(|e| format!("step {step}: apply: {e}"))?;
        if ack.applied != edges.len() {
            return Err(format!(
                "step {step}: ack.applied {} != batch size {}",
                ack.applied,
                edges.len()
            ));
        }
        for e in &edges {
            oracle.set(e.row, e.col, e.weight);
        }

        if step == remap_at {
            let gen_before = eng.generation();
            let report = eng.remap().map_err(|e| format!("step {step}: remap: {e}"))?;
            if report.generation != gen_before + 1 {
                return Err(format!(
                    "step {step}: remap generation {} after {gen_before}",
                    report.generation
                ));
            }
            if eng.pending() != 0 {
                return Err(format!(
                    "step {step}: {} overlay entries survived the fold",
                    eng.pending()
                ));
            }
        }

        // fresh host-CSR oracle of the mutated graph, rebuilt from scratch
        let truth = oracle.to_csr();
        let x = integer_vec(rng, dim);
        let want = truth.spmv(&x);
        let got = eng.mvm(&x).map_err(|e| format!("step {step}: mvm: {e}"))?;
        if got != want {
            return Err(format!(
                "step {step}: mvm diverged from the mutated-graph oracle (gen {})",
                eng.generation()
            ));
        }
        let xs: Vec<Vec<f64>> = (0..3).map(|_| integer_vec(rng, dim)).collect();
        let wants: Vec<Vec<f64>> = xs.iter().map(|x| truth.spmv(x)).collect();
        let ys = eng
            .execute(&xs, sharded)
            .map_err(|e| format!("step {step}: execute: {e}"))?;
        if ys != wants {
            return Err(format!(
                "step {step}: batched execute (sharded={sharded}, workers={workers}) \
                 diverged from the mutated-graph oracle"
            ));
        }
    }

    // a final fold, then one more exact answer on the drained engine
    eng.remap().map_err(|e| format!("final remap: {e}"))?;
    if eng.pending() != 0 {
        return Err("final remap left overlay entries".into());
    }
    let truth = oracle.to_csr();
    let x = integer_vec(rng, dim);
    if eng.mvm(&x).map_err(|e| format!("post-remap mvm: {e}"))? != truth.spmv(&x) {
        return Err("post-remap mvm diverged from the mutated-graph oracle".into());
    }
    if eng.remaps_total() != 2 {
        return Err(format!("expected 2 remaps, counted {}", eng.remaps_total()));
    }
    Ok(())
}

#[test]
fn fixed_block_streams_match_the_oracle_at_1_2_and_8_workers() {
    check("delta_fixed_block_stream", 3, |rng| {
        let dim = 64;
        for (i, &workers) in [1usize, 2, 8].iter().enumerate() {
            let m = integer_banded(rng, dim, 3);
            let sharded = i % 2 == 0;
            drive_stream(rng, m, Strategy::FixedBlock { block: 2 }, 8, workers, sharded)
                .map_err(|e| format!("workers {workers}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn direct_flat_plan_streams_match_the_oracle_and_go_composite_on_remap() {
    check("delta_direct_stream", 2, |rng| {
        // 80 nodes at grid 8 -> 10 cells, inside qm7_dyn4's 11-cell
        // window: builds the flat direct plan; the first remap recompiles
        // it as a (single-window) composite — both shapes must serve
        // exactly.
        let dim = 80;
        for &(workers, sharded) in &[(1usize, false), (8usize, true)] {
            let m = integer_banded(rng, dim, 2);
            drive_stream(
                rng,
                m,
                Strategy::Direct { controller: "qm7_dyn4".into() },
                8,
                workers,
                sharded,
            )
            .map_err(|e| format!("workers {workers}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn hierarchical_streams_match_the_oracle_across_windowed_remaps() {
    check("delta_hierarchical_stream", 2, |rng| {
        // 160 nodes at grid 4 -> 40 cells -> several overlapping
        // 11-cell controller windows per remap.
        let dim = 160;
        for &(workers, sharded) in &[(2usize, true), (8usize, false)] {
            let m = integer_banded(rng, dim, 2);
            drive_stream(
                rng,
                m,
                Strategy::Hierarchical { controller: "qm7_dyn4".into(), overlap: 2 },
                4,
                workers,
                sharded,
            )
            .map_err(|e| format!("workers {workers}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn post_remap_serving_matches_a_from_scratch_deployment() {
    check("delta_from_scratch_remap", 3, |rng| {
        let dim = 96;
        let m = integer_banded(rng, dim, 3);
        let strategies: [(Strategy, usize); 2] = [
            (Strategy::FixedBlock { block: 2 }, 8),
            (Strategy::Hierarchical { controller: "qm7_dyn4".into(), overlap: 2 }, 4),
        ];
        for (strategy, grid) in strategies {
            let dep = deploy(m.clone(), strategy.clone(), grid, 2)?;
            let pool = Arc::new(WorkerPool::new(2));
            let eng = DeltaEngine::attach(dep, pool).map_err(|e| format!("attach: {e}"))?;
            let mut oracle = Oracle::from_csr(&m);
            let edges = random_updates(rng, dim, 12);
            eng.apply(&edges).map_err(|e| format!("apply: {e}"))?;
            for e in &edges {
                oracle.set(e.row, e.col, e.weight);
            }
            eng.remap().map_err(|e| format!("remap: {e}"))?;

            // a brand-new deployment of the mutated matrix must serve
            // identically to the folded engine (integer-exact sums make
            // this independent of window/scheme arrangement)
            let mutated = oracle.to_csr();
            let fresh = deploy(mutated.clone(), strategy, grid, 2)?;
            let x = integer_vec(rng, dim);
            let want = fresh.mvm(&x).map_err(|e| format!("fresh mvm: {e}"))?;
            if want != mutated.spmv(&x) {
                return Err("fresh deployment diverged from its own matrix".into());
            }
            if eng.mvm(&x).map_err(|e| format!("engine mvm: {e}"))? != want {
                return Err("post-remap engine diverged from a from-scratch deployment".into());
            }
            for sharded in [false, true] {
                let ys = eng
                    .execute(&[x.clone()], sharded)
                    .map_err(|e| format!("execute: {e}"))?;
                if ys[0] != want {
                    return Err(format!(
                        "post-remap batched answer (sharded={sharded}) diverged \
                         from a from-scratch deployment"
                    ));
                }
            }
        }
        Ok(())
    });
}
