//! Mapping schemes: the paper's core objects.
//!
//! A *scheme* is a set of diagonal blocks plus fill blocks at the junctions
//! between consecutive diagonal blocks, expressed in grid units over a
//! [`GridSummary`]. This module implements:
//!
//! - action parsing (`parse_d` / `parse_f` of Algo. 3): 0/1 diagonal
//!   decisions → block sizes; fill decisions (binary or graded) → fill
//!   block sizes, masked by the diagonal sequence;
//! - geometry (matrix-unit rectangles, truncation at the matrix edge);
//! - validation (the paper's four principles: complete coverage capability,
//!   no overlap, simple coding, least area);
//! - evaluation (Eqs. 22–24): coverage ratio, area ratio, sparsity — O(1)
//!   per block via grid prefix sums;
//! - the scalarized reward (Eq. 21, with the area term sign-corrected, see
//!   DESIGN.md §3);
//! - composite schemes ([`composite`]): per-window schemes stitched into a
//!   globally valid mapping for matrices far beyond the controller's
//!   native grid, with off-window nnz accounted as digital spill.

pub mod composite;
pub mod eval;
pub mod parse;

pub use composite::{CompositeEval, CompositeScheme, WindowSlice};
pub use eval::{evaluate, EvalResult, RewardWeights};
pub use parse::{parse_actions, FillRule, Scheme};

use crate::graph::GridSummary;

/// A rectangle in *grid* coordinates (half-open), with its matrix-unit
/// geometry resolved against a grid summary on demand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridRect {
    pub r0: usize,
    pub r1: usize,
    pub c0: usize,
    pub c1: usize,
}

impl GridRect {
    pub fn square(g0: usize, len: usize) -> GridRect {
        GridRect {
            r0: g0,
            r1: g0 + len,
            c0: g0,
            c1: g0 + len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.r0 >= self.r1 || self.c0 >= self.c1
    }

    pub fn intersects(&self, other: &GridRect) -> bool {
        self.r0 < other.r1 && other.r0 < self.r1 && self.c0 < other.c1 && other.c0 < self.c1
    }

    /// Matrix-unit area (truncated at the matrix edge).
    pub fn area_units(&self, g: &GridSummary) -> u64 {
        g.rect_area(self.r0, self.r1, self.c0, self.c1)
    }

    /// Non-zeros inside the rectangle.
    pub fn nnz(&self, g: &GridSummary) -> u64 {
        g.nnz_rect(self.r0, self.r1, self.c0, self.c1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_basics() {
        let a = GridRect::square(2, 3);
        assert_eq!(a, GridRect { r0: 2, r1: 5, c0: 2, c1: 5 });
        assert!(!a.is_empty());
        assert!(GridRect { r0: 1, r1: 1, c0: 0, c1: 2 }.is_empty());
        let b = GridRect { r0: 4, r1: 6, c0: 0, c1: 3 };
        assert!(a.intersects(&b));
        let c = GridRect { r0: 5, r1: 6, c0: 0, c1: 2 };
        assert!(!a.intersects(&c));
    }
}
