//! Build-once, serve-forever deployments: the facade that turns
//! graph → reorder → map → compile → fleet hand-wiring into one builder
//! call, and a versioned on-disk bundle so the mapping cost is paid once.
//!
//! [`DeploymentBuilder`] names a *source* (a MatrixMarket file, a synthetic
//! R-MAT graph, or an in-memory CSR), a *strategy* (direct controller
//! inference, the hierarchical window mapper, or the fixed-block
//! baseline), and execution knobs (kernel selection, fleet banks and
//! policy, worker count). [`DeploymentBuilder::build`] runs the whole
//! pipeline and returns a [`Deployment`] owning the compiled plan (flat or
//! composite, behind [`DeployedPlan`]), the fleet assignment, the
//! reordering permutation, and provenance metadata.
//!
//! A deployment saves to a single self-contained JSON **bundle**
//! ([`Deployment::save`] / [`Deployment::load`], format version
//! [`BUNDLE_VERSION`]) that embeds the version-3 plan arena artifact (lane
//! alignment + the shared row-pattern table), the composite's spill CSR
//! when present, and the fleet/exec configuration — reloading is a pure
//! load + execute path with no graph, controller, or training dependency,
//! and it serves **bit-identically** to the in-memory deployment that
//! produced it. Bundles are byte-deterministic for a fixed source and
//! configuration. Bundle versions 1..=[`BUNDLE_VERSION`] all load: a v1
//! bundle's embedded v2 plan gains the pattern table and alignment on the
//! way in (see [`ExecPlan::from_json`]).
//!
//! Serving happens in *original* node ids: the builder's reordering
//! permutation rides along, [`Deployment::mvm`] applies x' = P x on the
//! way in and y = Pᵀ y' on the way out (the switch-circuit contract), so
//! callers never see the RCM order the crossbars were programmed in.

use super::error::{Error, Result};
use crate::agent::params::{init_params, load_checkpoint, Params};
use crate::agent::validate_fill_rule;
use crate::engine::{self, AssignPolicy, BatchExecutor, ExecPlan, Fleet, Servable, ServeStats};
use crate::graph::sparse::perm;
use crate::graph::{matrix_market, synth, Csr, GridSummary};
use crate::mapper::{self, cache, infer, CompositePlan, MapperConfig};
use crate::reorder::{reorder, Reordering};
use crate::runtime::manifest::ControllerEntry;
use crate::runtime::Manifest;
use crate::scheme::{CompositeScheme, FillRule, RewardWeights, Scheme, WindowSlice};
use crate::util::json::{num_arr, obj, Json};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// On-disk bundle format revision this build writes. Readers accept every
/// revision in `1..=BUNDLE_VERSION` (version 2 switched the embedded plan
/// artifact from v2 to v3 — lane-aligned arena + shared pattern table).
pub const BUNDLE_VERSION: usize = 2;

/// Where the matrix comes from.
#[derive(Clone, Debug)]
pub enum Source {
    /// A MatrixMarket `.mtx` file on disk.
    MtxFile(PathBuf),
    /// A deterministic synthetic R-MAT graph
    /// ([`crate::graph::synth::rmat_like`] with `target_nnz = nodes ·
    /// degree`, rounded to an even count).
    Rmat { nodes: usize, degree: usize, seed: u64 },
    /// An in-memory CSR the caller already holds.
    Matrix { label: String, matrix: Csr },
}

/// How the matrix is mapped onto crossbars.
#[derive(Clone, Debug)]
pub enum Strategy {
    /// One trained-controller inference over the whole grid — the paper's
    /// native path. Requires the grid to fit inside the controller's
    /// window; produces a flat [`ExecPlan`] with complete coverage.
    Direct { controller: String },
    /// The hierarchical window mapper ([`crate::mapper::map_graph`]):
    /// overlapping controller windows, scheme cache, stitched composite
    /// with digital spill — exact at any scale.
    Hierarchical { controller: String, overlap: usize },
    /// The fixed-block baseline: one diagonal block per `block` grid
    /// cells, off-block nnz spilled digitally — exact, no controller.
    FixedBlock { block: usize },
}

impl Strategy {
    fn label(&self) -> String {
        match self {
            Strategy::Direct { controller } => format!("direct:{controller}"),
            Strategy::Hierarchical { controller, overlap } => {
                format!("hierarchical:{controller}:overlap{overlap}")
            }
            Strategy::FixedBlock { block } => format!("fixed:{block}"),
        }
    }
}

/// Kernel selection applied to the compiled plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    /// density-threshold selection (the compiled default)
    Auto,
    /// force the dense row-dot kernel everywhere
    Dense,
    /// force the compiled CSR-within-tile kernel everywhere
    Sparse,
}

impl KernelChoice {
    pub fn parse(s: &str) -> Result<KernelChoice> {
        Ok(match s {
            "auto" => KernelChoice::Auto,
            "dense" => KernelChoice::Dense,
            "sparse" => KernelChoice::Sparse,
            other => {
                return Err(Error::Validate(format!(
                    "unknown kernel {other:?} (auto|dense|sparse)"
                )))
            }
        })
    }

    fn label(&self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Dense => "dense",
            KernelChoice::Sparse => "sparse",
        }
    }

    fn apply(&self, plan: &mut ExecPlan) {
        match self {
            KernelChoice::Auto => {}
            KernelChoice::Dense => plan.rekernel(0.0),
            KernelChoice::Sparse => plan.rekernel(f64::INFINITY),
        }
    }
}

/// The compiled artifact a deployment serves: either the engine's flat
/// plan or the mapper's composite. Both sides of the enum implement
/// [`Servable`], and so does the enum itself — the executor and the serve
/// loop never branch on the shape.
#[derive(Clone, Debug)]
pub enum DeployedPlan {
    Flat(ExecPlan),
    Composite(CompositePlan),
}

impl DeployedPlan {
    pub fn kind(&self) -> &'static str {
        match self {
            DeployedPlan::Flat(_) => "flat",
            DeployedPlan::Composite(_) => "composite",
        }
    }

    /// The merged crossbar schedule (the whole plan for flat deployments).
    pub fn exec_plan(&self) -> &ExecPlan {
        match self {
            DeployedPlan::Flat(p) => p,
            DeployedPlan::Composite(c) => &c.plan,
        }
    }

    pub(crate) fn exec_plan_mut(&mut self) -> &mut ExecPlan {
        match self {
            DeployedPlan::Flat(p) => p,
            DeployedPlan::Composite(c) => &mut c.plan,
        }
    }
}

impl DeployedPlan {
    /// The one Flat/Composite dispatch point: every [`Servable`] method
    /// delegates through this accessor, so adding a trait method cannot
    /// cross-wire enum arms.
    fn inner(&self) -> &dyn Servable {
        match self {
            DeployedPlan::Flat(p) => p,
            DeployedPlan::Composite(c) => c,
        }
    }
}

impl Servable for DeployedPlan {
    fn dim(&self) -> usize {
        self.inner().dim()
    }

    fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>) {
        self.inner().mvm_into(x, y)
    }

    fn shard_spans(&self, shards: usize) -> Vec<(usize, usize)> {
        self.inner().shard_spans(shards)
    }

    fn mvm_span_batch(&self, span: (usize, usize), xs: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        self.inner().mvm_span_batch(span, xs, outs)
    }

    fn nnz(&self) -> u64 {
        self.inner().nnz()
    }

    fn area_cells(&self) -> u64 {
        self.inner().area_cells()
    }

    fn stats(&self) -> ServeStats {
        self.inner().stats()
    }
}

/// Where a deployment came from — recorded in the bundle so a reloaded
/// artifact still answers "what is this".
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Provenance {
    /// source label, e.g. `rmat10000` or `mtx:data/qh882.mtx`
    pub source: String,
    /// strategy label, e.g. `hierarchical:qh882_dyn4:overlap4`
    pub strategy: String,
    /// matrix dimension D
    pub dim: usize,
    /// grid cell side K
    pub grid: usize,
    /// grid cells per side N
    pub cells: usize,
    /// total non-zeros of the source matrix
    pub nnz: u64,
    /// build seed (synthesis, parameter init, rollout streams)
    pub seed: u64,
    /// reordering label (`identity`|`cm`|`rcm`)
    pub reordering: String,
    /// kernel selection label (`auto`|`dense`|`sparse`)
    pub kernel: String,
}

/// A built (or reloaded) deployment: compiled plan + fleet + permutation +
/// provenance, ready to serve.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub provenance: Provenance,
    plan: Arc<DeployedPlan>,
    pub fleet: Fleet,
    /// reordering permutation, perm[new] = old
    perm: Vec<usize>,
    /// default executor worker count (overridable per executor)
    pub workers: usize,
    /// armed fault-tolerance harness (inject → detect → quarantine →
    /// repair); `None` until [`Deployment::arm_fault_harness`]. Shared via
    /// `Arc` so clones of the deployment observe the same fault state.
    fault: Option<Arc<crate::fault::FaultHarness>>,
}

/// Builder for [`Deployment`]: source + strategy, then optional knobs.
#[derive(Clone, Debug)]
pub struct DeploymentBuilder {
    source: Source,
    strategy: Strategy,
    grid: usize,
    reordering: Reordering,
    seed: u64,
    rounds: usize,
    checkpoint: Option<PathBuf>,
    kernel: KernelChoice,
    dense_threshold: Option<f64>,
    banks: usize,
    policy: AssignPolicy,
    workers: usize,
    reward_a: f64,
}

impl DeploymentBuilder {
    pub fn new(source: Source, strategy: Strategy) -> DeploymentBuilder {
        DeploymentBuilder {
            source,
            strategy,
            grid: 32,
            reordering: Reordering::ReverseCuthillMckee,
            seed: 42,
            rounds: 2,
            checkpoint: None,
            kernel: KernelChoice::Auto,
            dense_threshold: None,
            banks: 8,
            policy: AssignPolicy::BalancedNnz,
            workers: 8,
            reward_a: 0.8,
        }
    }

    /// Grid cell side K (default 32).
    pub fn grid(mut self, grid: usize) -> Self {
        self.grid = grid;
        self
    }

    /// Bandwidth-reducing reordering (default reverse Cuthill-McKee).
    pub fn reordering(mut self, r: Reordering) -> Self {
        self.reordering = r;
        self
    }

    /// Seed for synthesis, parameter init, and rollout streams.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Controller sampling rounds per window (0 = greedy + safety only).
    pub fn rounds(mut self, rounds: usize) -> Self {
        self.rounds = rounds;
        self
    }

    /// Load trained controller parameters from a checkpoint instead of
    /// fresh-initializing them.
    pub fn checkpoint(mut self, ck: PathBuf) -> Self {
        self.checkpoint = Some(ck);
        self
    }

    /// Kernel selection for the compiled plan (default auto).
    pub fn kernel(mut self, kernel: KernelChoice) -> Self {
        self.kernel = kernel;
        self
    }

    /// Density threshold for auto kernel selection: programs strictly
    /// below it run the compiled CSR-within-tile kernel, the rest the
    /// dense row-dot kernel (default
    /// [`crate::engine::plan::DEFAULT_SPARSE_THRESHOLD`]). Ignored when
    /// [`Self::kernel`] forces a kind — an explicit choice wins.
    pub fn dense_threshold(mut self, threshold: f64) -> Self {
        self.dense_threshold = Some(threshold);
        self
    }

    /// Simulated crossbar banks the fleet spreads tiles over (default 8).
    pub fn banks(mut self, banks: usize) -> Self {
        self.banks = banks;
        self
    }

    /// Tile → bank assignment policy (default nnz-balanced).
    pub fn policy(mut self, policy: AssignPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Default executor worker count (default 8); also the mapper's
    /// inference parallelism during the build.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Reward scalarization weight `a` used to score candidate window
    /// schemes during inference (default 0.8). Match the value the
    /// controller was trained with.
    pub fn reward_a(mut self, a: f64) -> Self {
        self.reward_a = a;
        self
    }

    fn controller_params(&self, controller: &str) -> Result<(ControllerEntry, Params)> {
        let entry = Manifest::builtin()
            .config(controller)
            .map_err(|e| Error::Validate(format!("{e:#}")))?
            .clone();
        let params = match &self.checkpoint {
            Some(ck) => {
                load_checkpoint(ck, &entry)
                    .map_err(|e| {
                        Error::Validate(format!("loading checkpoint {}: {e:#}", ck.display()))
                    })?
                    .0
            }
            None => init_params(&entry, self.seed),
        };
        Ok((entry, params))
    }

    fn infer_context(&self, entry: ControllerEntry, params: Params) -> Result<infer::InferContext> {
        let fill_rule = fill_rule_for(entry.fill_classes);
        validate_fill_rule(&entry, &fill_rule)
            .map_err(|e| Error::Validate(format!("{e:#}")))?;
        Ok(infer::InferContext {
            entry,
            params,
            fill_rule,
            weights: RewardWeights::new(self.reward_a),
            rounds: self.rounds,
            seed: self.seed,
        })
    }

    /// Run source → reorder → map → compile → fleet and assemble the
    /// deployment.
    pub fn build(self) -> Result<Deployment> {
        if self.grid == 0 {
            return Err(Error::Validate("grid cell side must be at least 1".into()));
        }
        let (label, m) = match &self.source {
            Source::MtxFile(p) => {
                let m = matrix_market::read(p).map_err(|e| match e {
                    matrix_market::MtxError::Io(io) => {
                        Error::Io(format!("reading {}: {io}", p.display()))
                    }
                    other => Error::Parse(format!("{}: {other}", p.display())),
                })?;
                (format!("mtx:{}", p.display()), m)
            }
            Source::Rmat { nodes, degree, seed } => {
                let (nodes, degree) = (*nodes, (*degree).max(1));
                if nodes < 2 {
                    return Err(Error::Validate(format!(
                        "rmat source needs at least 2 nodes, got {nodes}"
                    )));
                }
                // stay well inside simple-graph capacity: the skewed
                // R-MAT generator asserts (panics) on infeasible or
                // near-clique targets, which must surface as a typed
                // error at this boundary instead
                if degree > (nodes - 1) / 2 {
                    return Err(Error::Validate(format!(
                        "rmat degree {degree} is too dense for {nodes} nodes                          (need degree <= {})",
                        (nodes - 1) / 2
                    )));
                }
                let target_nnz = 2 * (nodes * degree / 2);
                (format!("rmat{nodes}"), synth::rmat_like(nodes, target_nnz, *seed))
            }
            Source::Matrix { label, matrix } => (label.clone(), matrix.clone()),
        };
        if m.rows != m.cols {
            return Err(Error::Validate(format!(
                "deployments need a square matrix, got {}x{}",
                m.rows, m.cols
            )));
        }
        if m.rows == 0 {
            return Err(Error::Validate("matrix has no rows".into()));
        }
        let total_nnz = m.nnz() as u64;
        let r = reorder(&m, self.reordering);
        let g = GridSummary::new(&r.matrix, self.grid);

        let mut plan = match &self.strategy {
            Strategy::Direct { controller } => {
                let (entry, params) = self.controller_params(controller)?;
                if g.n > entry.n {
                    return Err(Error::Validate(format!(
                        "direct strategy: the {}-cell grid exceeds controller {:?}'s \
                         {}-cell window; use Strategy::Hierarchical",
                        g.n, controller, entry.n
                    )));
                }
                let ctx = self.infer_context(entry, params)?;
                let sig = cache::signature(&g);
                let scheme = infer::map_window(&ctx, &g, sig.hash);
                let p = engine::compile(&r.matrix, &g, &scheme)
                    .map_err(|e| Error::Validate(format!("compiling direct scheme: {e:#}")))?;
                if p.mapped_nnz() != total_nnz {
                    return Err(Error::Validate(format!(
                        "direct scheme lost coverage: mapped {} of {} nnz",
                        p.mapped_nnz(),
                        total_nnz
                    )));
                }
                DeployedPlan::Flat(p)
            }
            Strategy::Hierarchical { controller, overlap } => {
                let (entry, params) = self.controller_params(controller)?;
                let cfg = MapperConfig {
                    infer: self.infer_context(entry, params)?,
                    overlap: *overlap,
                    workers: self.workers.max(1),
                };
                let (comp, _report) = mapper::map_graph(&g, &cfg)
                    .map_err(|e| Error::Validate(format!("mapping: {e:#}")))?;
                let cp = mapper::compile_composite(&r.matrix, &g, &comp)
                    .map_err(|e| Error::Validate(format!("compiling composite: {e:#}")))?;
                DeployedPlan::Composite(cp)
            }
            Strategy::FixedBlock { block } => {
                let block = (*block).clamp(1, g.n);
                // one full diagonal block per `block` grid cells, each
                // owning exactly its window — off-block nnz spills, so the
                // baseline serves exactly like the learned strategies
                let mut slices = Vec::new();
                let mut start = 0usize;
                while start < g.n {
                    let end = (start + block).min(g.n);
                    slices.push(WindowSlice {
                        win_start: start,
                        win_end: end,
                        start,
                        end,
                        scheme: Scheme {
                            diag_len: vec![end - start],
                            fill_len: vec![],
                        },
                        cache_hit: false,
                    });
                    start = end;
                }
                let comp = CompositeScheme { n: g.n, slices };
                let cp = mapper::compile_composite(&r.matrix, &g, &comp)
                    .map_err(|e| Error::Validate(format!("compiling fixed blocks: {e:#}")))?;
                DeployedPlan::Composite(cp)
            }
        };
        if Servable::nnz(&plan) != total_nnz {
            return Err(Error::Validate(format!(
                "plan serves {} nnz but the matrix holds {total_nnz}",
                Servable::nnz(&plan)
            )));
        }
        self.kernel.apply(plan.exec_plan_mut());
        if let (KernelChoice::Auto, Some(t)) = (self.kernel, self.dense_threshold) {
            plan.exec_plan_mut().rekernel(t);
        }
        let fleet = Fleet::assign(plan.exec_plan(), self.banks.max(1), self.policy)
            .map_err(|e| Error::Validate(format!("fleet assignment: {e:#}")))?;
        Ok(Deployment {
            provenance: Provenance {
                source: label,
                strategy: self.strategy.label(),
                dim: g.dim,
                grid: self.grid,
                cells: g.n,
                nnz: total_nnz,
                seed: self.seed,
                reordering: reordering_label(self.reordering).into(),
                kernel: self.kernel.label().into(),
            },
            plan: Arc::new(plan),
            fleet,
            perm: r.perm,
            workers: self.workers.max(1),
            fault: None,
        })
    }
}

/// Fill geometry implied by a controller's fill head.
pub(crate) fn fill_rule_for(fill_classes: usize) -> FillRule {
    match fill_classes {
        0 => FillRule::None,
        c => FillRule::Dynamic { grades: c.max(2) },
    }
}

fn reordering_label(r: Reordering) -> &'static str {
    match r {
        Reordering::Identity => "identity",
        Reordering::CuthillMckee => "cm",
        Reordering::ReverseCuthillMckee => "rcm",
    }
}

fn policy_label(p: AssignPolicy) -> &'static str {
    match p {
        AssignPolicy::RoundRobin => "rr",
        AssignPolicy::BalancedNnz => "balanced",
    }
}

impl Deployment {
    /// The compiled plan this deployment serves.
    pub fn plan(&self) -> &DeployedPlan {
        &self.plan
    }

    /// The same deployment serving a replacement plan of identical
    /// dimension: the reordering permutation, worker default, and
    /// provenance (nnz refreshed) carry over, the fleet is re-assigned for
    /// the new tile schedule, and any armed fault harness is dropped (it
    /// indexes the old plan's arena). This is the remap-swap primitive of
    /// [`crate::delta`].
    pub fn with_swapped_plan(&self, plan: DeployedPlan) -> Result<Deployment> {
        if plan.dim() != self.plan.dim() {
            return Err(Error::Validate(format!(
                "replacement plan serves dimension {}, deployment expects {}",
                plan.dim(),
                self.plan.dim()
            )));
        }
        let fleet = Fleet::assign(plan.exec_plan(), self.fleet.banks.max(1), self.fleet.policy)
            .map_err(|e| Error::Validate(format!("fleet assignment: {e:#}")))?;
        let mut provenance = self.provenance.clone();
        provenance.nnz = Servable::nnz(&plan);
        Ok(Deployment {
            provenance,
            plan: Arc::new(plan),
            fleet,
            perm: self.perm.clone(),
            workers: self.workers,
            fault: None,
        })
    }

    /// Shared handle to the plan (what executors hold).
    pub fn plan_arc(&self) -> Arc<DeployedPlan> {
        self.plan.clone()
    }

    /// Program-level serving statistics of the compiled plan. When a
    /// fault harness is armed its live health counters are overlaid on the
    /// otherwise all-zero `health` block.
    pub fn stats(&self) -> ServeStats {
        let mut s = self.plan.stats();
        if let Some(h) = &self.fault {
            s.health = h.health();
        }
        s
    }

    /// Arm a fault-tolerance harness on this deployment: snapshot the
    /// healthy program image, compute per-program ABFT column checksums
    /// and the exact digital reference, and route served MVMs through
    /// checksum verification (see [`crate::fault`]). Returns the shared
    /// harness handle (injection/repair control surface). Clones of the
    /// deployment made *after* arming share the same harness.
    pub fn arm_fault_harness(
        &mut self,
        opts: crate::fault::FaultOptions,
    ) -> Arc<crate::fault::FaultHarness> {
        let h = Arc::new(crate::fault::FaultHarness::new(
            self.plan.clone(),
            &self.fleet,
            opts,
        ));
        self.fault = Some(h.clone());
        h
    }

    /// The armed fault harness, if any.
    pub fn fault_harness(&self) -> Option<&Arc<crate::fault::FaultHarness>> {
        self.fault.as_ref()
    }

    /// Spawn an executor over the deployment's plan. `workers == 0` uses
    /// the deployment default.
    pub fn executor(&self, workers: usize) -> BatchExecutor<DeployedPlan> {
        let w = if workers == 0 { self.workers } else { workers };
        BatchExecutor::new(self.plan.clone(), w.max(1))
    }

    /// The reordering permutation (perm[new] = old).
    pub fn permutation(&self) -> &[usize] {
        &self.perm
    }

    /// x' = P x: take a request from original node ids into the served
    /// (reordered) order.
    pub fn permute_in(&self, x: &[f64]) -> Vec<f64> {
        perm::apply(&self.perm, x)
    }

    /// y = Pᵀ y': take a served response back to original node ids.
    pub fn permute_out(&self, y: &[f64]) -> Vec<f64> {
        perm::apply_inverse(&self.perm, y)
    }

    /// One exact MVM in original node ids (permute in, serve, permute
    /// out). The batch path is [`crate::api::serve_loop`] /
    /// [`Self::executor`].
    pub fn mvm(&self, x: &[f64]) -> Result<Vec<f64>> {
        let dim = self.plan.dim();
        if x.len() != dim {
            return Err(Error::Validate(format!(
                "request has {} elements, deployment expects {dim}",
                x.len()
            )));
        }
        Ok(self.permute_out(&self.plan.mvm(&self.permute_in(x))))
    }

    /// One exact MVM in original node ids through the *digital reference*
    /// (the host-CSR oracle an armed fault harness carries) instead of the
    /// crossbar arena. Falls back to [`Self::mvm`] when no harness is
    /// armed. Chaos harnesses use this as the ground truth that degraded
    /// answers must match bit for bit.
    pub fn mvm_oracle(&self, x: &[f64]) -> Result<Vec<f64>> {
        let Some(h) = &self.fault else {
            return self.mvm(x);
        };
        let dim = self.plan.dim();
        if x.len() != dim {
            return Err(Error::Validate(format!(
                "request has {} elements, deployment expects {dim}",
                x.len()
            )));
        }
        Ok(self.permute_out(&h.reference_mvm(&self.permute_in(x))))
    }

    // ---- bundle (de)serialization ---------------------------------------

    /// Serialize to the self-contained bundle document (format version
    /// [`BUNDLE_VERSION`], embedding the version-3 plan arena artifact).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("bundle_version", Json::Num(BUNDLE_VERSION as f64)),
            (
                "provenance",
                obj(vec![
                    ("source", Json::Str(self.provenance.source.clone())),
                    ("strategy", Json::Str(self.provenance.strategy.clone())),
                    ("dim", Json::Num(self.provenance.dim as f64)),
                    ("grid", Json::Num(self.provenance.grid as f64)),
                    ("cells", Json::Num(self.provenance.cells as f64)),
                    ("nnz", Json::Num(self.provenance.nnz as f64)),
                    ("seed", Json::Num(self.provenance.seed as f64)),
                    ("reordering", Json::Str(self.provenance.reordering.clone())),
                    ("kernel", Json::Str(self.provenance.kernel.clone())),
                ]),
            ),
            ("kind", Json::Str(self.plan.kind().into())),
            ("plan", self.plan.exec_plan().to_json()),
            ("perm", num_arr(self.perm.iter().map(|&p| p as f64))),
            (
                "fleet",
                obj(vec![
                    ("banks", Json::Num(self.fleet.banks as f64)),
                    ("policy", Json::Str(policy_label(self.fleet.policy).into())),
                ]),
            ),
            ("workers", Json::Num(self.workers as f64)),
        ];
        if let DeployedPlan::Composite(c) = &*self.plan {
            fields.push(("spill", c.spill.to_json()));
            fields.push((
                "window_tiles",
                num_arr(c.window_tiles.iter().map(|&t| t as f64)),
            ));
        }
        obj(fields)
    }

    /// Parse and validate a bundle document.
    pub fn from_json(doc: &Json) -> Result<Deployment> {
        let version = doc
            .get("bundle_version")
            .as_usize()
            .ok_or_else(|| Error::Parse("bundle missing bundle_version".into()))?;
        if !(1..=BUNDLE_VERSION).contains(&version) {
            return Err(Error::BundleVersion {
                found: version,
                supported: BUNDLE_VERSION,
            });
        }
        let prov = doc.get("provenance");
        let prov_str = |key: &str| -> Result<String> {
            prov.get(key)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| Error::Validate(format!("bundle provenance missing {key}")))
        };
        let prov_num = |key: &str| -> Result<u64> {
            prov.get(key)
                .as_i64()
                .and_then(|n| u64::try_from(n).ok())
                .ok_or_else(|| Error::Validate(format!("bundle provenance missing {key}")))
        };
        let provenance = Provenance {
            source: prov_str("source")?,
            strategy: prov_str("strategy")?,
            dim: prov_num("dim")? as usize,
            grid: prov_num("grid")? as usize,
            cells: prov_num("cells")? as usize,
            nnz: prov_num("nnz")?,
            seed: prov_num("seed")?,
            reordering: prov_str("reordering")?,
            kernel: prov_str("kernel")?,
        };

        let exec_plan = ExecPlan::from_json(doc.get("plan"))
            .map_err(|e| Error::Validate(format!("bundle plan: {e:#}")))?;
        if exec_plan.dim != provenance.dim {
            return Err(Error::Validate(format!(
                "bundle plan is {}-dimensional but provenance says {}",
                exec_plan.dim, provenance.dim
            )));
        }
        let kind = doc
            .get("kind")
            .as_str()
            .ok_or_else(|| Error::Validate("bundle missing kind".into()))?;
        let plan = match kind {
            "flat" => DeployedPlan::Flat(exec_plan),
            "composite" => {
                let spill = Csr::from_json(doc.get("spill"))
                    .map_err(|e| Error::Validate(format!("bundle spill: {e}")))?;
                if spill.rows != exec_plan.dim || spill.cols != exec_plan.dim {
                    return Err(Error::Validate(format!(
                        "bundle spill is {}x{} but the plan is {}-dimensional",
                        spill.rows, spill.cols, exec_plan.dim
                    )));
                }
                let wt_arr = doc
                    .get("window_tiles")
                    .as_arr()
                    .ok_or_else(|| Error::Validate("bundle missing window_tiles".into()))?;
                let mut window_tiles = Vec::with_capacity(wt_arr.len());
                for (i, v) in wt_arr.iter().enumerate() {
                    window_tiles.push(v.as_usize().ok_or_else(|| {
                        Error::Validate(format!("bundle window_tiles[{i}] not a count"))
                    })?);
                }
                if window_tiles.iter().sum::<usize>() != exec_plan.tiles.len() {
                    return Err(Error::Validate(format!(
                        "bundle window_tiles account for {} tiles but the plan holds {}",
                        window_tiles.iter().sum::<usize>(),
                        exec_plan.tiles.len()
                    )));
                }
                DeployedPlan::Composite(CompositePlan {
                    plan: exec_plan,
                    spill,
                    window_tiles,
                })
            }
            other => {
                return Err(Error::Validate(format!(
                    "unknown bundle kind {other:?} (flat|composite)"
                )))
            }
        };
        if Servable::nnz(&plan) != provenance.nnz {
            return Err(Error::Validate(format!(
                "bundle serves {} nnz but provenance records {}",
                Servable::nnz(&plan),
                provenance.nnz
            )));
        }

        let perm_arr = doc
            .get("perm")
            .as_arr()
            .ok_or_else(|| Error::Validate("bundle missing perm".into()))?;
        let mut permutation = Vec::with_capacity(perm_arr.len());
        for (i, v) in perm_arr.iter().enumerate() {
            permutation.push(
                v.as_usize()
                    .ok_or_else(|| Error::Validate(format!("bundle perm[{i}] not an index")))?,
            );
        }
        if permutation.len() != plan.dim() || !perm::is_permutation(&permutation) {
            return Err(Error::Validate(format!(
                "bundle perm is not a permutation of {} rows",
                plan.dim()
            )));
        }

        let fleet_doc = doc.get("fleet");
        let banks = fleet_doc
            .get("banks")
            .as_usize()
            .filter(|&b| b >= 1)
            .ok_or_else(|| Error::Validate("bundle fleet needs at least one bank".into()))?;
        let policy = AssignPolicy::parse(
            fleet_doc
                .get("policy")
                .as_str()
                .ok_or_else(|| Error::Validate("bundle fleet missing policy".into()))?,
        )
        .map_err(|e| Error::Validate(format!("{e:#}")))?;
        let fleet = Fleet::assign(plan.exec_plan(), banks, policy)
            .map_err(|e| Error::Validate(format!("bundle fleet assignment: {e:#}")))?;
        let workers = doc.get("workers").as_usize().unwrap_or(1).max(1);

        Ok(Deployment {
            provenance,
            plan: Arc::new(plan),
            fleet,
            perm: permutation,
            workers,
            fault: None,
        })
    }

    /// Write the bundle to disk (compact JSON).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| Error::Io(format!("writing bundle {}: {e}", path.display())))
    }

    /// Load a bundle from disk — the pure load + execute path: no graph,
    /// controller, or training dependency, bit-identical serving to the
    /// deployment that was saved.
    pub fn load(path: &Path) -> Result<Deployment> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("reading bundle {}: {e}", path.display())))?;
        let doc = Json::parse(&text)
            .map_err(|e| Error::Parse(format!("bundle {}: {e}", path.display())))?;
        Self::from_json(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qm7_source() -> Source {
        Source::Matrix {
            label: "qm7".into(),
            matrix: synth::qm7_like(5828),
        }
    }

    #[test]
    fn fixed_block_deployment_serves_exactly_in_original_ids() {
        let m = synth::qm7_like(5828);
        let dep = DeploymentBuilder::new(qm7_source(), Strategy::FixedBlock { block: 2 })
            .grid(2)
            .banks(2)
            .workers(2)
            .build()
            .unwrap();
        assert_eq!(dep.provenance.dim, 22);
        assert_eq!(dep.stats().total_nnz(), m.nnz() as u64);
        let x: Vec<f64> = (0..22).map(|i| ((i * 5) % 7) as f64 - 3.0).collect();
        // exact in ORIGINAL ids despite the RCM reordering inside
        assert_eq!(dep.mvm(&x).unwrap(), m.spmv(&x));
        // wrong-length requests are a typed validation error
        assert!(matches!(dep.mvm(&[1.0, 2.0]), Err(Error::Validate(_))));
    }

    #[test]
    fn direct_strategy_requires_a_fitting_window_and_is_complete() {
        // qm7 at grid 2 -> n = 11, exactly qm7_dyn4's 11-cell window
        let dep = DeploymentBuilder::new(
            qm7_source(),
            Strategy::Direct { controller: "qm7_dyn4".into() },
        )
        .grid(2)
        .rounds(1)
        .build()
        .unwrap();
        assert_eq!(dep.plan().kind(), "flat");
        let m = synth::qm7_like(5828);
        assert_eq!(dep.stats().mapped_nnz, m.nnz() as u64);
        assert_eq!(dep.stats().spilled_nnz, 0);
        let x: Vec<f64> = (0..22).map(|i| (i as f64 * 0.37).sin()).collect();
        let y = dep.mvm(&x).unwrap();
        let want = m.spmv(&x);
        for (a, b) in y.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        // a grid larger than the controller window is rejected with advice
        let err = DeploymentBuilder::new(
            Source::Rmat { nodes: 2000, degree: 4, seed: 3 },
            Strategy::Direct { controller: "qm7_dyn4".into() },
        )
        .grid(8)
        .build()
        .unwrap_err();
        assert!(matches!(err, Error::Validate(_)));
        assert!(err.to_string().contains("Hierarchical"));
    }

    #[test]
    fn old_bundle_versions_load_and_future_ones_are_rejected() {
        let dep = DeploymentBuilder::new(qm7_source(), Strategy::FixedBlock { block: 2 })
            .grid(2)
            .build()
            .unwrap();
        let x: Vec<f64> = (0..22).map(|i| ((i * 7) % 11) as f64 - 5.0).collect();
        let want = dep.mvm(&x).unwrap();
        // a v1 bundle: the old header over the old embedded v2 plan
        // artifact — must load, backfilling pattern table + alignment
        let mut doc = dep.to_json();
        if let Json::Obj(map) = &mut doc {
            map.insert("bundle_version".into(), Json::Num(1.0));
            map.insert("plan".into(), dep.plan().exec_plan().to_json_v2());
        } else {
            panic!("bundle must serialize to an object");
        }
        let v1 = Deployment::from_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(v1.mvm(&x).unwrap(), want, "v1 bundle must serve bit-identically");
        assert_eq!(v1.plan().exec_plan(), dep.plan().exec_plan());
        // a future revision is a typed bundle_version error
        if let Json::Obj(map) = &mut doc {
            map.insert("bundle_version".into(), Json::Num((BUNDLE_VERSION + 1) as f64));
        }
        let err = Deployment::from_json(&doc).unwrap_err();
        assert!(matches!(err, Error::BundleVersion { .. }));
        assert_eq!(err.kind(), "bundle_version");
    }

    #[test]
    fn dense_threshold_tunes_the_auto_mix_but_not_the_answers() {
        let x: Vec<f64> = (0..22).map(|i| ((i * 3) % 13) as f64 - 6.0).collect();
        let build = |b: DeploymentBuilder| b.grid(2).build().unwrap();
        let mk = || DeploymentBuilder::new(qm7_source(), Strategy::FixedBlock { block: 1 });
        // threshold above every density -> all sparse; zero -> all dense
        let lo = build(mk().dense_threshold(0.0));
        let hi = build(mk().dense_threshold(1.1));
        assert_eq!(lo.stats().kernel_sparse, 0);
        assert_eq!(hi.stats().kernel_dense, 0);
        assert_eq!(lo.mvm(&x).unwrap(), hi.mvm(&x).unwrap());
        // an explicit kernel choice wins over the threshold
        let forced = build(mk().kernel(KernelChoice::Sparse).dense_threshold(0.0));
        assert_eq!(forced.stats().kernel_dense, 0);
        assert_eq!(forced.mvm(&x).unwrap(), lo.mvm(&x).unwrap());
    }

    #[test]
    fn kernel_choices_change_the_mix_but_not_the_answers() {
        let x: Vec<f64> = (0..22).map(|i| ((i * 3) % 13) as f64 - 6.0).collect();
        let build = |k: KernelChoice| {
            DeploymentBuilder::new(qm7_source(), Strategy::FixedBlock { block: 1 })
                .grid(2)
                .kernel(k)
                .build()
                .unwrap()
        };
        let dense = build(KernelChoice::Dense);
        let sparse = build(KernelChoice::Sparse);
        assert_eq!(dense.stats().kernel_sparse, 0);
        assert_eq!(sparse.stats().kernel_dense, 0);
        assert_eq!(dense.mvm(&x).unwrap(), sparse.mvm(&x).unwrap());
        assert_eq!(KernelChoice::parse("sparse").unwrap(), KernelChoice::Sparse);
        assert!(KernelChoice::parse("quantum").is_err());
    }
}
