//! Bench: full training epochs end-to-end — one bench per paper table's
//! workload class. `epochs/s` here × the paper's 40k-epoch budget gives
//! the full-reproduction wall time quoted in EXPERIMENTS.md.
//!
//!   Table II  → qm7  (grid 2,  N=11)
//!   Table IV  → qh882 (grid 32, N=28) and qh1484 (grid 32, N=47)

use autogmap::agent::{TrainOptions, Trainer};
use autogmap::coordinator::config::Dataset;
use autogmap::coordinator::dataset::load_matrix;
use autogmap::graph::GridSummary;
use autogmap::reorder::{reorder, Reordering};
use autogmap::runtime::Runtime;
use autogmap::scheme::{FillRule, RewardWeights};
use autogmap::util::bench::Bencher;

fn main() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP end_to_end bench: {e}");
            return;
        }
    };
    if rt.manifest().is_err() {
        println!("SKIP end_to_end bench: no manifest (run `make artifacts`)");
        return;
    }
    let manifest = rt.manifest().unwrap();
    let mut b = Bencher::new();
    let specs: [(&str, Dataset, usize, &str, FillRule); 4] = [
        (
            "table2_qm7_epoch",
            Dataset::Qm7 { seed: 5828 },
            2,
            "qm7_dyn4",
            FillRule::Dynamic { grades: 4 },
        ),
        (
            "table2_qm7_epoch_B32",
            Dataset::Qm7 { seed: 5828 },
            2,
            "qm7_dyn4_b32",
            FillRule::Dynamic { grades: 4 },
        ),
        (
            "table4_qh882_epoch",
            Dataset::Qh882 { seed: 882 },
            32,
            "qh882_dyn6",
            FillRule::Dynamic { grades: 6 },
        ),
        (
            "table4_qh1484_epoch",
            Dataset::Qh1484 { seed: 1484 },
            32,
            "qh1484_dyn6",
            FillRule::Dynamic { grades: 6 },
        ),
    ];
    for (name, ds, grid_size, controller, rule) in specs {
        let m = load_matrix(&ds).unwrap();
        let r = reorder(&m, Reordering::CuthillMckee);
        let grid = GridSummary::new(&r.matrix, grid_size);
        let entry = manifest.config(controller).unwrap().clone();
        let opts = TrainOptions {
            weights: RewardWeights::new(0.8),
            fill_rule: rule,
            ..Default::default()
        };
        let batch = entry.batch;
        let mut trainer = Trainer::new(&rt, entry, opts).unwrap();
        let stats = b.bench(name, || trainer.epoch(&grid).unwrap());
        println!(
            "  -> {:.0} epochs/s ({:.0} episodes/s); paper's 40k-epoch budget ≈ {:.0}s at this rate",
            1.0 / stats.median_s,
            batch as f64 / stats.median_s,
            40_000.0 * stats.median_s
        );
    }
}
