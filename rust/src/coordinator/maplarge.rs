//! `map-large` driver: R-MAT graph → RCM → hierarchical mapper → composite
//! plan → fleet-sharded serving, with a machine-readable perf ledger
//! (`BENCH_mapper.json`) tracking mapped nnz/s at 1/2/8 workers, serving
//! throughput in both executor modes (scalar per-request baseline vs
//! band-sharded multi-RHS), the global area ratio against the fixed-block
//! baseline at the same window size, and the scheme-cache hit rate.

use crate::agent::params::{self, Params};
use crate::agent::{TrainOptions, Trainer};
use crate::baselines;
use crate::crossbar::cost::CostModel;
use crate::engine::{self, AssignPolicy, BatchExecutor, Fleet, TraceKind};
use crate::graph::{synth, GridSummary};
use crate::mapper::{self, MapperConfig};
use crate::reorder::{reorder, Reordering};
use crate::runtime::Manifest;
use crate::scheme::{CompositeEval, FillRule, RewardWeights};
use crate::util::bench;
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Everything the `map-large` subcommand needs.
pub struct MapLargeOptions {
    pub nodes: usize,
    /// average degree of the synthetic R-MAT graph
    pub degree: usize,
    pub grid: usize,
    pub seed: u64,
    /// built-in controller config name (window size = its grid count)
    pub controller: String,
    pub overlap: usize,
    pub rounds: usize,
    /// serving worker threads (mapping is benchmarked at 1/2/8 regardless)
    pub workers: usize,
    pub banks: usize,
    pub requests: usize,
    pub batch: usize,
    /// optional warmup: REINFORCE epochs on the densest window before
    /// mapping (0 = epoch-free inference, the fresh-checkout path)
    pub epochs: usize,
    /// optional trained checkpoint to load controller params from
    pub checkpoint: Option<PathBuf>,
    pub bench_json: PathBuf,
}

impl Default for MapLargeOptions {
    fn default() -> Self {
        MapLargeOptions {
            nodes: 100_000,
            degree: 8,
            grid: 32,
            seed: 42,
            controller: "qh882_dyn4".into(),
            overlap: 4,
            rounds: 4,
            workers: 8,
            banks: 8,
            requests: 64,
            batch: 16,
            epochs: 0,
            checkpoint: None,
            bench_json: PathBuf::from("BENCH_mapper.json"),
        }
    }
}

/// Fill geometry implied by a controller's fill head.
fn fill_rule_for(fill_classes: usize) -> FillRule {
    match fill_classes {
        0 => FillRule::None,
        c => FillRule::Dynamic { grades: c.max(2) },
    }
}

/// One mapped scale: composite stats the bench ledger records.
struct ScaleResult {
    eval: CompositeEval,
    baseline_area: f64,
    /// controller window size in grid cells
    window_cells: usize,
    windows: usize,
    unique_windows: usize,
    cache_entries: usize,
    cache_hit_rate: f64,
    /// mapping throughput per worker count in `WORKER_COUNTS` order
    mapped_nnz_per_s: [f64; 3],
}

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

/// Build the graph, map it, and evaluate vs. the fixed-block baseline.
///
/// `full` runs the primary point: optional REINFORCE warmup plus the
/// 1/2/8-worker mapping sweep for the throughput ledger (the composite is
/// bit-deterministic across worker counts; the last run's is returned).
/// The secondary comparison point passes `full = false`: epoch-free
/// params and a single mapping pass — only its area/baseline/cache-hit
/// numbers enter the ledger, so the sweep would be pure waste.
fn map_scale(
    opts: &MapLargeOptions,
    nodes: usize,
    full: bool,
    verbose: bool,
) -> Result<(crate::graph::Csr, GridSummary, crate::scheme::CompositeScheme, ScaleResult)> {
    let target_nnz = 2 * (nodes * opts.degree / 2);
    let t0 = Instant::now();
    let m = synth::rmat_like(nodes, target_nnz, opts.seed);
    let r = reorder(&m, Reordering::ReverseCuthillMckee);
    if verbose {
        println!(
            "  graph: {nodes} nodes, {} nnz (sparsity {:.6}), RCM bandwidth {} -> {} ({:.1}s)",
            m.nnz(),
            m.sparsity(),
            r.bandwidth_before,
            r.bandwidth_after,
            t0.elapsed().as_secs_f64()
        );
    }
    let g = GridSummary::new(&r.matrix, opts.grid);

    let entry = Manifest::builtin()
        .config(&opts.controller)
        .with_context(|| format!("map-large needs a built-in controller, got {:?}", opts.controller))?
        .clone();
    let fill_rule = fill_rule_for(entry.fill_classes);
    let weights = RewardWeights::new(0.8);

    // controller parameters: checkpoint > warmup training > fresh init
    let params: Params = if let Some(ck) = &opts.checkpoint {
        let (p, _, epoch, _) = params::load_checkpoint(ck, &entry)?;
        if verbose {
            println!("  params: checkpoint {} (epoch {epoch})", ck.display());
        }
        p
    } else if full && opts.epochs > 0 && g.n >= entry.n {
        // warmup: train on the densest window, then map with the result
        let spans = mapper::window::plan_windows(g.n, entry.n, opts.overlap);
        let densest = spans
            .iter()
            .max_by_key(|s| g.nnz_rect(s.start, s.end, s.start, s.end))
            .expect("at least one window");
        let local = g.window(densest.start, densest.len());
        let topts = TrainOptions {
            fill_rule,
            weights,
            seed: opts.seed,
            workers: opts.workers.max(1),
            ..Default::default()
        };
        let mut trainer = Trainer::native(entry.clone(), topts)?;
        for _ in 0..opts.epochs {
            trainer.epoch(&local)?;
        }
        if verbose {
            println!(
                "  params: {} warmup epochs on the densest window [{}, {})",
                opts.epochs, densest.start, densest.end
            );
        }
        trainer.params()?
    } else {
        params::init_params(&entry, opts.seed)
    };

    // map at fixed worker counts for the throughput ledger; the composite
    // is bit-identical across counts, keep the last
    let cfg_for = |workers: usize| MapperConfig {
        infer: mapper::InferContext {
            entry: entry.clone(),
            params: params.clone(),
            fill_rule,
            weights,
            rounds: opts.rounds,
            seed: opts.seed,
        },
        overlap: opts.overlap,
        workers,
    };
    let mut mapped_nnz_per_s = [0f64; 3];
    let mut last = None;
    if full {
        for (i, &w) in WORKER_COUNTS.iter().enumerate() {
            let (comp, report) = mapper::map_graph(&g, &cfg_for(w))?;
            mapped_nnz_per_s[i] = m.nnz() as f64 / report.wall_seconds.max(1e-9);
            last = Some((comp, report));
        }
    } else {
        let (comp, report) = mapper::map_graph(&g, &cfg_for(opts.workers.max(1)))?;
        let rate = m.nnz() as f64 / report.wall_seconds.max(1e-9);
        mapped_nnz_per_s = [rate; 3];
        last = Some((comp, report));
    }
    let (comp, report) = last.expect("at least one mapping run");

    let eval = comp.evaluate(&g, 4);
    // fixed-block baseline at the same window size: one diagonal block per
    // `entry.n` grid cells, the partition a windowing scheme without a
    // learned controller would emit
    let baseline = baselines::vanilla(g.n, entry.n);
    let baseline_area = crate::scheme::evaluate(&baseline, &g, weights).area_ratio;
    if verbose {
        println!(
            "  mapped: {} windows ({} unique, cache hit rate {:.1}%), nnz/s w1/w2/w8 = {:.2e}/{:.2e}/{:.2e}",
            report.windows,
            report.unique_windows,
            report.cache_hit_rate * 100.0,
            mapped_nnz_per_s[0],
            mapped_nnz_per_s[1],
            mapped_nnz_per_s[2]
        );
        println!(
            "  composite: area {:.5} vs fixed-block {:.5} ({:.2}x better), windowed coverage {:.4}, \
             mapped {:.1}% of nnz, spill {} nnz ({} KiB COO)",
            eval.area_ratio,
            baseline_area,
            baseline_area / eval.area_ratio.max(1e-12),
            eval.coverage_windowed,
            eval.mapped_fraction * 100.0,
            eval.spilled_nnz,
            eval.spill_coo_bytes / 1024
        );
    }
    Ok((
        r.matrix,
        g,
        comp,
        ScaleResult {
            eval,
            baseline_area,
            window_cells: entry.n,
            windows: report.windows,
            unique_windows: report.unique_windows,
            cache_entries: report.cache_entries,
            cache_hit_rate: report.cache_hit_rate,
            mapped_nnz_per_s,
        },
    ))
}

/// Run `map-large` end-to-end and write the bench ledger.
pub fn run_map_large(opts: &MapLargeOptions) -> Result<()> {
    ensure!(opts.nodes >= 64, "map-large wants at least 64 nodes");
    println!(
        "map-large: {} nodes, degree {}, grid {}, controller {} (seed {})",
        opts.nodes, opts.degree, opts.grid, opts.controller, opts.seed
    );
    let (matrix, g, comp, scale) = map_scale(opts, opts.nodes, true, true)?;
    ensure!(
        scale.eval.coverage_windowed >= 1.0 - 1e-12,
        "composite lost windowed coverage: {}",
        scale.eval.coverage_windowed
    );

    // compile per-window plans, merge, shard across the fleet
    let t0 = Instant::now();
    let cplan = mapper::compile_composite(&matrix, &g, &comp)?;
    let fleet = Fleet::assign(&cplan.plan, opts.banks.max(1), AssignPolicy::BalancedNnz)?;
    let cost = CostModel::default();
    println!(
        "  plan: {} tiles over {} windows ({} programs, {:.1}% elision) compiled in {:.1}s; \
         fleet {} banks, imbalance {:.3}, mvm {:.2} us / {:.2} nJ; spill {} nnz digital",
        cplan.plan.tiles.len(),
        cplan.window_tiles.len(),
        cplan.plan.num_programs(),
        cplan.plan.elision_ratio() * 100.0,
        t0.elapsed().as_secs_f64(),
        fleet.banks,
        fleet.imbalance(),
        fleet.mvm_latency_ns(&cost) / 1e3,
        fleet.mvm_energy_pj(&cost) / 1e3,
        cplan.spilled_nnz()
    );

    // serve a synthetic trace through the one generic executor (the same
    // `BatchExecutor` that serves flat plans — composites go through the
    // `Servable` trait), in both modes: scalar per-request (the seed
    // serving mode, the in-run baseline) and band-sharded multi-RHS (the
    // optimized mode)
    let trace = engine::synth_trace(
        TraceKind::Uniform,
        g.dim,
        opts.requests.max(1),
        opts.batch.max(1),
        &[(0, g.dim)],
        0x5eed,
    );
    let (kernel_dense, kernel_sparse) = cplan.plan.kernel_counts();
    let cplan = Arc::new(cplan);
    let exec = BatchExecutor::new(cplan.clone(), opts.workers.max(1));
    // ledger tripwire: before any throughput number is recorded, both
    // executor modes must reproduce the scalar composite MVM bit for bit
    // on the first trace batch — the generic-executor rewiring must not
    // move a single ulp
    let want: Vec<Vec<f64>> = trace[0].iter().map(|x| cplan.mvm(x)).collect();
    let probe = exec.execute_batch(trace[0].clone());
    ensure!(
        probe == want,
        "generic executor (scalar mode) diverged from the composite MVM"
    );
    exec.recycle(probe);
    let probe = exec.execute_batch_sharded(trace[0].clone());
    ensure!(
        probe == want,
        "generic executor (sharded mode) diverged from the composite MVM"
    );
    exec.recycle(probe); // doubles as buffer-pool warmup
    let t0 = Instant::now();
    for batch_reqs in &trace {
        let ys = exec.execute_batch(batch_reqs.clone());
        exec.recycle(ys);
    }
    let scalar_wall = t0.elapsed().as_secs_f64();
    let scalar_rps = opts.requests as f64 / scalar_wall;
    exec.recycle(exec.execute_batch_sharded(trace[0].clone())); // warm the sharded path
    let mut latencies_ms = Vec::with_capacity(opts.requests);
    let t0 = Instant::now();
    for batch_reqs in &trace {
        let tb = Instant::now();
        let ys = exec.execute_batch_sharded(batch_reqs.clone());
        let dt_ms = tb.elapsed().as_secs_f64() * 1e3;
        latencies_ms.extend(std::iter::repeat(dt_ms).take(ys.len()));
        exec.recycle(ys);
    }
    let wall = t0.elapsed().as_secs_f64();
    let throughput = opts.requests as f64 / wall;
    let p50 = bench::percentile(&latencies_ms, 50.0);
    let p99 = bench::percentile(&latencies_ms, 99.0);
    println!(
        "  serve: {} requests, {} workers, kernels {kernel_dense} dense / {kernel_sparse} sparse: \
         scalar {:.0} req/s; sharded multi-RHS {:.0} req/s ({:.2}x), p50 {:.3} ms, p99 {:.3} ms",
        opts.requests,
        opts.workers.max(1),
        scalar_rps,
        throughput,
        throughput / scalar_rps.max(1e-12),
        p50,
        p99
    );

    // secondary scale point at 10k nodes so the ledger tracks the area
    // trajectory at both paper-plus and production scale (skipped for
    // runs at or below that scale — they ARE the small point)
    let small = if opts.nodes > 10_000 {
        println!("  10k-node comparison point (epoch-free, single pass):");
        let (_, _, _, s) = map_scale(opts, 10_000, false, true)?;
        Some(s)
    } else {
        None
    };

    let better = scale.eval.area_ratio < scale.baseline_area;
    println!(
        "  area check: composite {:.5} {} fixed-block {:.5}",
        scale.eval.area_ratio,
        if better { "<" } else { "NOT <" },
        scale.baseline_area
    );

    let mut fields = vec![
        ("bench", Json::Str("mapper".into())),
        ("nodes", Json::Num(opts.nodes as f64)),
        ("nnz", Json::Num(scale.eval.total_nnz as f64)),
        ("grid", Json::Num(opts.grid as f64)),
        ("controller", Json::Str(opts.controller.clone())),
        ("window_cells", Json::Num(scale.window_cells as f64)),
        ("windows", Json::Num(scale.windows as f64)),
        ("unique_windows", Json::Num(scale.unique_windows as f64)),
        ("cache_entries", Json::Num(scale.cache_entries as f64)),
        ("cache_hit_rate", Json::Num(scale.cache_hit_rate)),
        ("mapped_nnz_per_s_w1", Json::Num(scale.mapped_nnz_per_s[0])),
        ("mapped_nnz_per_s_w2", Json::Num(scale.mapped_nnz_per_s[1])),
        ("mapped_nnz_per_s_w8", Json::Num(scale.mapped_nnz_per_s[2])),
        ("area_ratio", Json::Num(scale.eval.area_ratio)),
        ("baseline_area_ratio", Json::Num(scale.baseline_area)),
        (
            "area_vs_baseline",
            Json::Num(scale.eval.area_ratio / scale.baseline_area.max(1e-300)),
        ),
        ("coverage_windowed", Json::Num(scale.eval.coverage_windowed)),
        ("mapped_fraction", Json::Num(scale.eval.mapped_fraction)),
        ("spilled_nnz", Json::Num(scale.eval.spilled_nnz as f64)),
        ("spill_coo_bytes", Json::Num(scale.eval.spill_coo_bytes as f64)),
        ("placed_tiles", Json::Num(cplan.plan.tiles.len() as f64)),
        ("programs", Json::Num(cplan.plan.num_programs() as f64)),
        ("elision_ratio", Json::Num(cplan.plan.elision_ratio())),
        ("banks", Json::Num(fleet.banks as f64)),
        ("fleet_imbalance", Json::Num(fleet.imbalance())),
        ("fleet_latency_ns", Json::Num(fleet.mvm_latency_ns(&cost))),
        ("fleet_energy_pj", Json::Num(fleet.mvm_energy_pj(&cost))),
        ("kernel_dense_programs", Json::Num(kernel_dense as f64)),
        ("kernel_sparse_programs", Json::Num(kernel_sparse as f64)),
        ("workers", Json::Num(opts.workers as f64)),
        ("requests", Json::Num(opts.requests as f64)),
        // the baseline here is the request-parallel scalar executor at
        // --workers (serve-bench's single-thread baseline is named
        // scalar_rps there; this matches its parallel_scalar_rps field)
        ("parallel_scalar_rps", Json::Num(scalar_rps)),
        ("throughput_rps", Json::Num(throughput)),
        (
            "serve_speedup_vs_parallel_scalar",
            Json::Num(throughput / scalar_rps.max(1e-300)),
        ),
        ("p50_ms", Json::Num(p50)),
        ("p99_ms", Json::Num(p99)),
    ];
    if let Some(s) = &small {
        fields.push(("area_ratio_10k", Json::Num(s.eval.area_ratio)));
        fields.push(("baseline_area_ratio_10k", Json::Num(s.baseline_area)));
        fields.push(("cache_hit_rate_10k", Json::Num(s.cache_hit_rate)));
    }
    bench::write_bench_json(&opts.bench_json, fields)?;
    println!("wrote {}", opts.bench_json.display());
    ensure!(
        better,
        "composite area ratio {} is not better than the fixed-block baseline {}",
        scale.eval.area_ratio,
        scale.baseline_area
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_rules_follow_the_controller_head() {
        assert_eq!(fill_rule_for(0), FillRule::None);
        assert_eq!(fill_rule_for(4), FillRule::Dynamic { grades: 4 });
        assert_eq!(fill_rule_for(6), FillRule::Dynamic { grades: 6 });
    }

    #[test]
    fn map_large_small_run_end_to_end() {
        // a miniature full run: completes, writes the ledger, beats the
        // fixed-block baseline
        let dir = std::env::temp_dir().join("autogmap_maplarge_test");
        std::fs::create_dir_all(&dir).unwrap();
        let opts = MapLargeOptions {
            nodes: 2000,
            degree: 6,
            grid: 8,
            rounds: 1,
            requests: 8,
            batch: 4,
            workers: 2,
            banks: 2,
            controller: "qm7_dyn4".into(),
            bench_json: dir.join("BENCH_mapper.json"),
            ..Default::default()
        };
        run_map_large(&opts).unwrap();
        let text = std::fs::read_to_string(&opts.bench_json).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(doc.get("bench").as_str(), Some("mapper"));
        let area = doc.get("area_ratio").as_f64().unwrap();
        let base = doc.get("baseline_area_ratio").as_f64().unwrap();
        assert!(area < base, "area {area} must beat baseline {base}");
        assert!(doc.get("cache_hit_rate").as_f64().unwrap() >= 0.0);
        let entries = doc.get("cache_entries").as_f64().unwrap();
        let unique = doc.get("unique_windows").as_f64().unwrap();
        assert!(entries >= 1.0 && entries == unique, "fresh-cache run: entries {entries} == unique {unique}");
        assert!(doc.get("mapped_nnz_per_s_w1").as_f64().unwrap() > 0.0);
    }
}
