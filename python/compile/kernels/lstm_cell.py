"""L1 Pallas kernel: fused LSTM cell (Eqs. 9-14 of the paper).

The controller's per-decision-point compute hot-spot. All four gates are
computed from a single MXU-shaped matmul ``[B, I+H] @ [I+H, 4H]`` followed by
fused elementwise gate math, mirroring how the analog crossbar fuses
multiply (Ohm) and accumulate (Kirchhoff) in one array pass:

    z = [x, h_prev] @ W + b            # one matmul, 4H output lanes
    f, i, g, o = split(z, 4)           # forget/input/cell/output gates
    c = sigmoid(f) * c_prev + sigmoid(i) * tanh(g)
    h = sigmoid(o) * tanh(c)

Gate packing order is (f, i, g, o) — ``ref.py`` and the Rust mirror
(`agent::lstm`) must agree.

The kernel keeps the whole ``[B, I+H]`` activation tile and the
``[I+H, 4H]`` weight tile VMEM-resident (controller sizes: H ≤ 64,
B ≤ 256 ⇒ ≤ 0.6 MiB at f32, far under the ~16 MiB VMEM budget), so the
BlockSpec is a single block; the HBM↔VMEM schedule is one load per step.

``interpret=True`` always: CPU PJRT cannot execute Mosaic custom-calls; the
real-TPU mapping is an estimate documented in DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(xh_ref, w_ref, b_ref, c_prev_ref, h_ref, c_ref):
    """Fused gates: one matmul + elementwise, all VMEM-resident."""
    z = jnp.dot(xh_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...][None, :]
    hidden = c_prev_ref.shape[-1]
    f = jax.nn.sigmoid(z[:, 0 * hidden : 1 * hidden])
    i = jax.nn.sigmoid(z[:, 1 * hidden : 2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(z[:, 3 * hidden : 4 * hidden])
    c = f * c_prev_ref[...] + i * g
    h_ref[...] = o * jnp.tanh(c)
    c_ref[...] = c


@functools.partial(jax.jit, static_argnames=())
def lstm_cell(x, h_prev, c_prev, w, b):
    """One LSTM step.

    Args:
      x:      [B, I]  input at this decision point.
      h_prev: [B, H]  previous hidden state.
      c_prev: [B, H]  previous cell state.
      w:      [I+H, 4H] packed gate weights (f,i,g,o).
      b:      [4H]    packed gate biases.

    Returns:
      (h, c): both [B, H].
    """
    batch, _ = x.shape
    hidden = h_prev.shape[-1]
    xh = jnp.concatenate([x, h_prev], axis=-1)
    out_shape = (
        jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
        jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
    )
    return pl.pallas_call(
        _lstm_cell_kernel,
        out_shape=out_shape,
        interpret=True,
    )(xh.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32),
      c_prev.astype(jnp.float32))
