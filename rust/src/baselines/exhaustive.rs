//! Exhaustive search over diagonal partitions — the "violent solution" the
//! paper rules out at O(2^N) (§IV). Practical for N ≤ 20; used to verify
//! the DP oracle and to ground-truth small RL runs.

use crate::graph::GridSummary;
use crate::scheme::{evaluate, EvalResult, FillRule, parse_actions, RewardWeights, Scheme};

/// Best scheme over all 2^(N-1) diagonal partitions (no fill), maximizing
/// the scalarized reward. Returns the scheme and its evaluation.
pub fn best_diagonal(g: &GridSummary, w: RewardWeights) -> (Scheme, EvalResult) {
    let n = g.n;
    assert!(n >= 1 && n <= 24, "exhaustive search limited to N<=24 cells");
    let mut best: Option<(Scheme, EvalResult)> = None;
    let combos = 1u64 << (n - 1);
    for bits in 0..combos {
        let d: Vec<u8> = (0..n - 1).map(|i| ((bits >> i) & 1) as u8).collect();
        let s = parse_actions(n, &d, &[], FillRule::None);
        let e = evaluate(&s, g, w);
        let better = match &best {
            None => true,
            Some((_, be)) => e.reward > be.reward,
        };
        if better {
            best = Some((s, e));
        }
    }
    best.unwrap()
}

/// Best *complete-coverage* diagonal partition by area (exhaustive).
/// Returns `None` if no complete-coverage partition exists other than ones
/// that exist trivially — the full block always qualifies, so this is
/// always `Some` in practice.
pub fn best_complete_diagonal(g: &GridSummary) -> Option<(Scheme, EvalResult)> {
    let n = g.n;
    assert!(n >= 1 && n <= 24, "exhaustive search limited to N<=24 cells");
    let w = RewardWeights::new(0.5);
    let mut best: Option<(Scheme, EvalResult)> = None;
    for bits in 0..(1u64 << (n - 1)) {
        let d: Vec<u8> = (0..n - 1).map(|i| ((bits >> i) & 1) as u8).collect();
        let s = parse_actions(n, &d, &[], FillRule::None);
        let e = evaluate(&s, g, w);
        if e.coverage_ratio < 1.0 {
            continue;
        }
        let better = match &best {
            None => true,
            Some((_, be)) => e.covered_area_units < be.covered_area_units,
        };
        if better {
            best = Some((s, e));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::oracle;
    use crate::graph::sparse::Coo;
    use crate::graph::GridSummary;
    use crate::util::propcheck::check;

    #[test]
    fn exhaustive_agrees_with_dp_oracle_property() {
        check("exhaustive_vs_dp", 15, |rng| {
            let dim = 6 + rng.below(9) as usize; // N = dim (grid 1), <= 14
            let mut coo = Coo::new(dim, dim);
            for i in 0..dim {
                coo.push(i, i, 1.0);
            }
            for _ in 0..dim {
                let a = rng.below(dim as u64) as usize;
                let b = (a + 1 + rng.below(3) as usize).min(dim - 1);
                if a != b {
                    coo.push_sym(b, a, 1.0);
                }
            }
            let g = GridSummary::new(&coo.to_csr(), 1);
            let (ex_scheme, ex_eval) = best_complete_diagonal(&g).unwrap();
            let dp = oracle::optimal_diagonal(&g).unwrap();
            let dp_area = oracle::partition_area(&g, &dp.diag_len);
            if dp_area != ex_eval.covered_area_units {
                return Err(format!(
                    "dp {:?} area {dp_area} != exhaustive {:?} area {}",
                    dp.diag_len, ex_scheme.diag_len, ex_eval.covered_area_units
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn reward_maximizer_trades_coverage_for_area() {
        // isolated far-off-diagonal entry: with a low coverage weight the
        // best reward scheme sacrifices that entry; with a=1 coverage wins.
        let mut coo = Coo::new(10, 10);
        for i in 0..10 {
            coo.push(i, i, 1.0);
        }
        coo.push_sym(9, 0, 1.0);
        let g = GridSummary::new(&coo.to_csr(), 1);
        let (_, low_a) = best_diagonal(&g, RewardWeights::new(0.3));
        assert!(low_a.coverage_ratio < 1.0);
        let (s_high, high_a) = best_diagonal(&g, RewardWeights::new(1.0));
        assert_eq!(high_a.coverage_ratio, 1.0);
        assert_eq!(s_high.diag_len.iter().sum::<usize>(), 10);
    }
}
