//! Visualization: spy plots with scheme overlays (Figs. 7/8/10/12) and
//! ASCII training curves (Figs. 9/11/13).
//!
//! Two backends: terminal ASCII (quick inspection) and standalone SVG
//! files (the figure artifacts recorded by `autogmap reproduce --figure N`).

use crate::graph::{Csr, GridSummary};
use crate::scheme::Scheme;
use std::fmt::Write as _;

/// ASCII spy plot of a matrix, downsampled to at most `max_side` character
/// cells; `#` marks a cell containing at least one non-zero.
pub fn ascii_spy(m: &Csr, max_side: usize) -> String {
    let n = m.rows.max(1);
    let step = n.div_ceil(max_side.max(1));
    let side = n.div_ceil(step);
    let mut cells = vec![false; side * side];
    for r in 0..m.rows {
        for &c in m.row(r) {
            cells[(r / step) * side + c / step] = true;
        }
    }
    let mut out = String::new();
    for r in 0..side {
        for c in 0..side {
            out.push(if cells[r * side + c] { '#' } else { '.' });
        }
        out.push('\n');
    }
    out
}

/// ASCII spy plot with the scheme's blocks overlaid: `#` nnz inside a
/// block, `!` nnz OUTSIDE every block (uncovered), `+` empty block cell,
/// `.` empty uncovered cell. One character per grid cell.
pub fn ascii_scheme(m: &Csr, g: &GridSummary, scheme: &Scheme) -> String {
    let n = g.n;
    let mut in_block = vec![false; n * n];
    for rect in scheme.rects() {
        for r in rect.r0..rect.r1.min(n) {
            for c in rect.c0..rect.c1.min(n) {
                in_block[r * n + c] = true;
            }
        }
    }
    let mut out = String::new();
    for r in 0..n {
        for c in 0..n {
            let nnz = g.cell_nnz[r * n + c] > 0;
            let blk = in_block[r * n + c];
            out.push(match (nnz, blk) {
                (true, true) => '#',
                (true, false) => '!',
                (false, true) => '+',
                (false, false) => '.',
            });
        }
        out.push('\n');
    }
    let _ = m; // matrix-level detail intentionally reduced to grid cells
    out
}

/// SVG spy plot with translucent scheme rectangles — the paper-figure
/// artifact (Figs. 8/10/12 analogue).
pub fn svg_scheme(m: &Csr, g: &GridSummary, scheme: Option<&Scheme>, title: &str) -> String {
    let dim = m.rows as f64;
    let size = 640.0;
    let scale = size / dim;
    let mut s = String::new();
    let _ = writeln!(
        s,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{0}" height="{1}" viewBox="-2 -20 {2} {3}">"#,
        size + 4.0,
        size + 26.0,
        size + 4.0,
        size + 26.0
    );
    let _ = writeln!(
        s,
        r#"<text x="0" y="-6" font-family="monospace" font-size="12">{}</text>"#,
        title
    );
    let _ = writeln!(
        s,
        r#"<rect x="0" y="0" width="{size}" height="{size}" fill="white" stroke="black" stroke-width="0.5"/>"#
    );
    // non-zeros
    let px = (scale).max(0.75);
    for r in 0..m.rows {
        for &c in m.row(r) {
            let _ = writeln!(
                s,
                r#"<rect x="{:.2}" y="{:.2}" width="{px:.2}" height="{px:.2}" fill="black"/>"#,
                c as f64 * scale,
                r as f64 * scale,
            );
        }
    }
    // scheme blocks
    if let Some(scheme) = scheme {
        for rect in scheme.rects() {
            let x = (rect.c0 * g.grid) as f64 * scale;
            let y = (rect.r0 * g.grid) as f64 * scale;
            let w = (g.span_units(rect.c0, rect.c1 - rect.c0)) as f64 * scale;
            let h = (g.span_units(rect.r0, rect.r1 - rect.r0)) as f64 * scale;
            let _ = writeln!(
                s,
                r#"<rect x="{x:.2}" y="{y:.2}" width="{w:.2}" height="{h:.2}" fill="steelblue" fill-opacity="0.35" stroke="steelblue" stroke-width="1"/>"#
            );
        }
    }
    s.push_str("</svg>\n");
    s
}

/// ASCII line chart for training curves: series of (label, values) drawn
/// into a `width` x `height` character canvas with shared x (epoch) axis,
/// one glyph per series. Values are min/max-normalized per chart.
pub fn ascii_chart(series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    assert!(!series.is_empty());
    let glyphs = ['*', 'o', '+', 'x', '@'];
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, v)| v.iter().copied())
        .filter(|v| v.is_finite())
        .collect();
    let (lo, hi) = all
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
    let span = if (hi - lo).abs() < 1e-12 { 1.0 } else { hi - lo };
    let mut canvas = vec![vec![' '; width]; height];
    for (si, (_, vals)) in series.iter().enumerate() {
        if vals.is_empty() {
            continue;
        }
        let glyph = glyphs[si % glyphs.len()];
        for x in 0..width {
            let idx = x * vals.len().saturating_sub(1) / width.saturating_sub(1).max(1);
            let v = vals[idx.min(vals.len() - 1)];
            if !v.is_finite() {
                continue;
            }
            let yf = (v - lo) / span;
            let y = ((1.0 - yf) * (height - 1) as f64).round() as usize;
            canvas[y.min(height - 1)][x] = glyph;
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "{hi:>10.4} ┐");
    for row in &canvas {
        out.push_str("           │");
        out.extend(row.iter());
        out.push('\n');
    }
    let _ = writeln!(out, "{lo:>10.4} ┴{}", "─".repeat(width));
    let mut legend = String::from("            ");
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = write!(legend, "{}={}  ", glyphs[si % glyphs.len()], name);
    }
    out.push_str(&legend);
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::scheme::{parse_actions, FillRule};

    #[test]
    fn spy_plot_shape() {
        let m = synth::qm7_like(5828);
        let s = ascii_spy(&m, 22);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 22);
        assert!(lines.iter().all(|l| l.len() == 22));
        assert_eq!(
            s.chars().filter(|&c| c == '#').count(),
            m.nnz() // no downsampling at full resolution
        );
    }

    #[test]
    fn spy_plot_downsamples() {
        let m = synth::qh882_like(1);
        let s = ascii_spy(&m, 60);
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines.len() <= 60);
    }

    #[test]
    fn scheme_overlay_marks_uncovered() {
        let m = synth::qm7_like(5828);
        let r = crate::reorder::reorder(&m, crate::reorder::Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 2);
        // unit blocks, no fill -> off-diagonal nnz must show as '!'
        let scheme = parse_actions(g.n, &[0; 10], &[0; 10], FillRule::None);
        let s = ascii_scheme(&r.matrix, &g, &scheme);
        assert!(s.contains('!'), "uncovered nnz must be flagged:\n{s}");
        // full block -> nothing uncovered
        let full = Scheme { diag_len: vec![g.n], fill_len: vec![] };
        let s = ascii_scheme(&r.matrix, &g, &full);
        assert!(!s.contains('!'));
    }

    #[test]
    fn svg_is_wellformed_enough() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2);
        let scheme = parse_actions(g.n, &[0; 10], &[0; 10], FillRule::None);
        let svg = svg_scheme(&m, &g, Some(&scheme), "test");
        assert!(svg.starts_with("<svg"));
        assert!(svg.trim_end().ends_with("</svg>"));
        assert!(svg.matches("<rect").count() > m.nnz());
    }

    #[test]
    fn chart_renders_all_series() {
        let a: Vec<f64> = (0..100).map(|i| (i as f64 / 10.0).sin()).collect();
        let b: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let s = ascii_chart(&[("sin", &a), ("lin", &b)], 60, 12);
        assert!(s.contains('*') && s.contains('o'));
        assert!(s.contains("sin") && s.contains("lin"));
    }

    #[test]
    fn chart_handles_constant_series() {
        let a = vec![0.5; 10];
        let s = ascii_chart(&[("const", &a)], 20, 5);
        assert!(s.contains('*'));
    }
}
