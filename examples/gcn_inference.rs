//! End-to-end driver: spectral GCN inference through a mapped deployment.
//!
//! This is the workload the paper's §III motivates (Eq. 1): the GCN's
//! normalized adjacency Â is the sparse matrix mapped onto crossbars. The
//! pipeline exercised here is the production stack end to end:
//!
//!   synth R-MAT graph → Â = D̂^{-1/2}(A+I)D̂^{-1/2} → api facade
//!   (RCM reorder → fixed-block mapping → compiled plan arena) →
//!   multi-layer GCN forward, one multi-RHS batch per layer
//!
//! and then demonstrates the point of the `algo` layer: the *same* mapped
//! asset answers PageRank and BFS without reprogramming a single cell —
//! the crossbar always computes y = Âx, and each algorithm's semiring
//! lives in the digital post-step.
//!
//! Every path is verified: GCN features against the dense per-layer
//! oracle (≤ 1e-5), BFS levels bit-identical to the queue reference, and
//! PageRank against the host-CSR run of the same iteration loop.
//!
//! Run: `cargo run --release --example gcn_inference`
//! (pure native path — no artifacts, controller, or training required)

use autogmap::algo::{
    bfs, bfs_reference, gcn_forward, max_abs_diff, normalized_adjacency, pagerank, BfsOptions,
    CsrEngine, DeploymentEngine, GcnLayer, PageRankOptions,
};
use autogmap::api::{DeploymentBuilder, Source, Strategy};
use autogmap::engine::Servable;
use autogmap::graph::synth;
use autogmap::util::rng::Pcg64;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // --- the GCN workload: a 1000-node R-MAT graph's normalized adjacency
    let nodes = 1000;
    let a = synth::rmat_like(nodes, nodes * 8, 42);
    let a_norm = normalized_adjacency(&a);
    println!(
        "graph: {nodes} nodes, {} nnz; Â (self-loops added): {} nnz",
        a.nnz(),
        a_norm.nnz()
    );

    // --- map Â once through the api facade (fresh-checkout native path:
    // fixed-block strategy needs no trained controller)
    let t0 = Instant::now();
    let dep = DeploymentBuilder::new(
        Source::Matrix { label: "gcn_rmat1k".into(), matrix: a_norm.clone() },
        Strategy::FixedBlock { block: 4 },
    )
    .grid(16)
    .workers(4)
    .build()?;
    println!(
        "mapped in {:.2}s: dim {}, plan nnz {}, {} area cells",
        t0.elapsed().as_secs_f64(),
        dep.plan().dim(),
        dep.plan().nnz(),
        dep.plan().area_cells()
    );
    let exec = dep.executor(0);
    let engine = DeploymentEngine::new(&dep, &exec, true);

    // --- two-layer GCN forward: one multi-RHS engine batch per layer
    let (f_in, f_hidden, f_out) = (8, 16, 4);
    let layers = vec![
        GcnLayer::random(f_in, f_hidden, true, 1),
        GcnLayer::random(f_hidden, f_out, false, 2),
    ];
    let mut rng = Pcg64::seed_from_u64(3);
    let z0: Vec<f64> = (0..nodes * f_in).map(|_| rng.uniform(-1.0, 1.0)).collect();

    let t0 = Instant::now();
    let dense = layers[1].forward_dense(&a_norm, &layers[0].forward_dense(&a_norm, &z0));
    let dense_time = t0.elapsed();

    let (mapped, trace) = gcn_forward(&engine, &z0, &layers)?;
    let diff = max_abs_diff(&dense, &mapped);
    println!(
        "\n2-layer GCN ({f_in}→{f_hidden}→{f_out}): max|Δ| vs dense oracle = {diff:.2e}  \
         (dense {dense_time:?}, mapped {:.3}s, {} MVMs, {:.2e} nnz/s)",
        trace.wall_s,
        trace.mvms,
        trace.nnz_per_s()
    );
    anyhow::ensure!(diff <= 1e-5, "mapped GCN diverged from the dense oracle: {diff:e}");

    // --- the same mapped asset, different algorithms: the semiring is a
    // digital post-step, the programmed arena never changes

    // BFS levels must be bit-identical to the queue-based reference
    let (levels, bfs_trace) = bfs(&engine, &BfsOptions { source: 0, max_levels: 0 })?;
    anyhow::ensure!(
        levels == bfs_reference(&a_norm, 0),
        "mapped BFS diverged from the queue reference"
    );
    let reached = levels.iter().filter(|&&l| l >= 0).count();
    println!(
        "BFS from node 0: {reached}/{nodes} reached in {} levels, bit-identical to the \
         queue reference ({} MVMs)",
        bfs_trace.iterations,
        bfs_trace.mvms
    );

    // PageRank: same iteration loop on the mapped engine and the host CSR
    let pr_opts = PageRankOptions::default();
    let (ranks, pr_trace) = pagerank(&engine, &pr_opts)?;
    let (ranks_ref, _) = pagerank(&CsrEngine(&a_norm), &pr_opts)?;
    let pr_diff = max_abs_diff(&ranks, &ranks_ref);
    anyhow::ensure!(pr_diff <= 1e-8, "mapped PageRank diverged from the CSR run: {pr_diff:e}");
    let mass: f64 = ranks.iter().sum();
    println!(
        "PageRank: converged {} in {} iterations (final residual {:.2e}), mass {mass:.12}, \
         max|Δ| vs CSR run = {pr_diff:.2e}",
        pr_trace.converged,
        pr_trace.iterations,
        pr_trace.residuals.last().copied().unwrap_or(0.0)
    );

    println!(
        "\nend-to-end OK: one mapped bundle answered GCN, BFS, and PageRank — \
         semirings in the post-step, arena untouched"
    );
    Ok(())
}
