//! Bench: crossbar simulator MVM throughput — the deployment-side compute
//! (Fig. 5) — plus the programming models (quantization / variation).

use autogmap::crossbar::{place, program};
use autogmap::graph::{synth, GridSummary};
use autogmap::reorder::{reorder, Reordering};
use autogmap::scheme::Scheme;
use autogmap::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    for (name, m, grid) in [
        ("qm7_g2", synth::qm7_like(5828), 2usize),
        ("qh882_g32", synth::qh882_like(882), 32),
        ("qh1484_g32", synth::qh1484_like(1484), 32),
    ] {
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, grid);
        // a realistic trained-scheme stand-in: unit diagonal + unit fills
        let scheme = Scheme {
            diag_len: vec![1; g.n],
            fill_len: vec![1; g.n - 1],
        };
        let arr = place(&r.matrix, &g, &scheme).unwrap();
        let x: Vec<f64> = (0..g.dim).map(|i| (i as f64 * 0.1).sin()).collect();
        b.bench(&format!("place/{name}"), || {
            place(&r.matrix, &g, &scheme).unwrap()
        });
        b.bench(&format!("mvm/{name} ({} tiles)", arr.tiles.len()), || {
            black_box(arr.mvm(&x))
        });
        b.bench(&format!("spmv_ref/{name}"), || black_box(r.matrix.spmv(&x)));
        b.bench(&format!("quantize8/{name}"), || program::quantize(&arr, 8));
        b.bench(&format!("perturb/{name}"), || {
            program::perturb(&arr, 0.05, 1)
        });
    }
}
