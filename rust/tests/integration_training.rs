//! End-to-end tests of the native training backend. Unlike the PJRT
//! integration tests, nothing here needs `artifacts/` — this is the
//! paper's training loop running on a fresh checkout.

use autogmap::agent::{BackendKind, TrainOptions, Trainer};
use autogmap::coordinator::config::{Dataset, ExperimentConfig};
use autogmap::coordinator::metrics::read_csv;
use autogmap::coordinator::runner::build_trainer;
use autogmap::coordinator::{run_experiment, RunnerOptions};
use autogmap::graph::GridSummary;
use autogmap::reorder::{reorder, Reordering};
use autogmap::runtime::Manifest;
use autogmap::scheme::{FillRule, RewardWeights};

fn qm7_cfg(name: &str, epochs: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        dataset: Dataset::Qm7 { seed: 5828 },
        grid: 2,
        reordering: Reordering::CuthillMckee,
        controller: "qm7_dyn4".into(),
        fill_rule: FillRule::Dynamic { grades: 4 },
        reward_a: 0.8,
        lr: 0.02,
        ent_coef: 0.002,
        baseline_decay: 0.95,
        epochs,
        seed,
        log_every: 25,
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("autogmap_it_native_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn native_backend_trains_qm7_to_complete_coverage() {
    // The acceptance run: `train --backend native` with no artifacts/
    // present must reach a complete-coverage scheme cheaper than the
    // monolithic crossbar, and the reward signal must actually improve.
    let tmp = tmp_dir("e2e");
    let cfg = qm7_cfg("nt_e2e", 1200, 5828);
    let opts = RunnerOptions {
        out_root: tmp.clone(),
        backend: BackendKind::Native,
        workers: 2,
        keep_history: true,
        ..Default::default()
    };
    let result = run_experiment(None, &cfg, &opts).unwrap();

    let best = result.best.as_ref().expect("no complete-coverage scheme found");
    assert_eq!(best.eval.coverage_ratio, 1.0);
    assert!(
        best.eval.area_ratio < 1.0,
        "best complete-coverage area must shrink below the full block, got {}",
        best.eval.area_ratio
    );
    best.scheme.validate(result.workload.grid.n).unwrap();

    // learning signal: last-quarter mean reward above first-quarter
    let h = &result.history;
    assert_eq!(h.len(), cfg.epochs);
    assert!(h.iter().all(|s| s.loss.is_finite() && s.mean_logp.is_finite()));
    let q = h.len() / 4;
    let early: f64 = h[..q].iter().map(|s| s.mean_reward).sum::<f64>() / q as f64;
    let late: f64 = h[h.len() - q..].iter().map(|s| s.mean_reward).sum::<f64>() / q as f64;
    assert!(
        late > early,
        "mean reward did not improve: {early:.4} -> {late:.4}"
    );

    // run artifacts written exactly like a PJRT run
    let cols = read_csv(&result.run_dir.join("metrics.csv")).unwrap();
    assert!(!cols[0].1.is_empty());
    assert!(result.run_dir.join("summary.json").exists());
}

#[test]
fn native_training_is_deterministic_across_worker_counts() {
    let run = |workers: usize| {
        let cfg = qm7_cfg("nt_det", 40, 7);
        let opts = RunnerOptions {
            out_root: tmp_dir(&format!("det_w{workers}")),
            backend: BackendKind::Native,
            workers,
            keep_history: true,
            ..Default::default()
        };
        run_experiment(None, &cfg, &opts).unwrap()
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.history.len(), b.history.len());
    for (x, y) in a.history.iter().zip(b.history.iter()) {
        assert_eq!(x.epoch, y.epoch);
        assert_eq!(x.mean_reward.to_bits(), y.mean_reward.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.max_reward.to_bits(), y.max_reward.to_bits());
        assert_eq!(x.baseline.to_bits(), y.baseline.to_bits());
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.mean_logp.to_bits(), y.mean_logp.to_bits());
    }
    // and the tracked best solutions agree
    assert_eq!(
        a.best.as_ref().map(|s| s.scheme.clone()),
        b.best.as_ref().map(|s| s.scheme.clone())
    );
}

#[test]
fn resume_from_checkpoint_matches_uninterrupted_run() {
    let m = autogmap::graph::synth::qm7_like(5828);
    let r = reorder(&m, Reordering::CuthillMckee);
    let grid = GridSummary::new(&r.matrix, 2);
    let entry = Manifest::builtin().config("qm7_dyn4").unwrap().clone();
    let topts = TrainOptions {
        lr: 0.02,
        ent_coef: 0.002,
        weights: RewardWeights::new(0.8),
        fill_rule: FillRule::Dynamic { grades: 4 },
        seed: 11,
        workers: 2,
        ..Default::default()
    };

    // uninterrupted: 12 epochs
    let mut a = Trainer::native(entry.clone(), topts).unwrap();
    let mut stats_a = Vec::new();
    for _ in 0..12 {
        stats_a.push(a.epoch(&grid).unwrap());
    }

    // interrupted: 6 epochs, checkpoint, fresh trainer, restore, 6 more
    let dir = tmp_dir("resume");
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("checkpoint.json");
    let mut b = Trainer::native(entry.clone(), topts).unwrap();
    for _ in 0..6 {
        b.epoch(&grid).unwrap();
    }
    b.save_checkpoint(&ck).unwrap();

    let mut c = Trainer::native(entry, topts).unwrap();
    c.restore(&ck).unwrap();
    assert_eq!(c.epoch, 6);
    let mut stats_c = Vec::new();
    for _ in 0..6 {
        stats_c.push(c.epoch(&grid).unwrap());
    }

    // epoch stats 6..12 must be identical to the uninterrupted run's
    for (x, y) in stats_a[6..].iter().zip(stats_c.iter()) {
        assert_eq!(x.epoch, y.epoch);
        assert_eq!(x.mean_reward.to_bits(), y.mean_reward.to_bits(), "epoch {}", x.epoch);
        assert_eq!(x.baseline.to_bits(), y.baseline.to_bits());
        assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        assert_eq!(x.mean_logp.to_bits(), y.mean_logp.to_bits());
    }
    assert_eq!(a.params().unwrap(), c.params().unwrap());
}

#[test]
fn explicit_pjrt_without_artifacts_is_an_actionable_error() {
    // both train and reproduce route through build_trainer, so this is
    // the error every artifact-less `--backend pjrt` invocation hits
    let rt = autogmap::runtime::Runtime::new("/nonexistent_autogmap_artifacts").unwrap();
    let topts = TrainOptions {
        fill_rule: FillRule::Dynamic { grades: 4 },
        ..Default::default()
    };
    let err = build_trainer(Some(&rt), "qm7_dyn4", topts, BackendKind::Pjrt).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("--backend native"), "unhelpful error: {msg}");
    assert!(msg.contains("make artifacts"), "should mention the build path: {msg}");
}

#[test]
fn native_handles_bilstm_and_diag_only_configs_end_to_end() {
    let m = autogmap::graph::synth::qm7_like(5828);
    let r = reorder(&m, Reordering::CuthillMckee);
    let grid = GridSummary::new(&r.matrix, 2);
    for (controller, rule) in [
        ("qm7_diag", FillRule::None),
        ("qm7_fill_bilstm", FillRule::Fixed { size: 2 }),
        ("qm7_dyn6", FillRule::Dynamic { grades: 6 }),
    ] {
        let topts = TrainOptions {
            lr: 0.02,
            fill_rule: rule,
            weights: RewardWeights::new(0.8),
            seed: 3,
            workers: 2,
            ..Default::default()
        };
        let mut trainer = build_trainer(None, controller, topts, BackendKind::Native).unwrap();
        for _ in 0..10 {
            let s = trainer.epoch(&grid).unwrap();
            assert!(s.loss.is_finite(), "{controller}");
        }
        let (scheme, eval) = trainer.greedy(&grid).unwrap();
        scheme.validate(grid.n).unwrap();
        assert!(eval.reward.is_finite());
    }
}
