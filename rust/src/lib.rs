//! # AutoGMap
//!
//! Reproduction of *"AutoGMap: Learning to Map Large-scale Sparse Graphs on
//! Memristive Crossbars"* (Lyu et al., IEEE TNNLS 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the coordinator — RL training loop, environment,
//!   baselines, Cuthill-McKee reordering, crossbar simulator, CLI.
//! - **L2 (python/compile/model.py)**: the LSTM controller rollout and the
//!   REINFORCE+Adam train step, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/)**: Pallas kernels (fused LSTM cell,
//!   blocked crossbar MVM) called from L2.
//!
//! Python never runs at request time: `make artifacts` lowers the L1/L2
//! computations once; the Rust binary loads them through PJRT.

pub mod agent;
pub mod baselines;
pub mod coordinator;
pub mod crossbar;
pub mod gcn;
pub mod graph;
pub mod reorder;
pub mod runtime;
pub mod scheme;
pub mod util;
pub mod viz;
