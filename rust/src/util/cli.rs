//! Tiny CLI argument parser (no `clap` in the vendored crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and subcommands. Each binary declares the options it accepts; unknown
//! options are hard errors so typos never silently fall through.

use std::collections::BTreeMap;

/// Parsed command line: subcommand (if declared), options, positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `value_opts` lists options that take a value;
    /// `flag_opts` lists boolean flags; `has_subcommand` consumes the first
    /// positional as a subcommand name.
    pub fn parse(
        argv: &[String],
        value_opts: &[&str],
        flag_opts: &[&str],
        has_subcommand: bool,
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if flag_opts.contains(&key.as_str()) {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} does not take a value"));
                    }
                    out.flags.push(key);
                } else if value_opts.contains(&key.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    out.opts.insert(key, val);
                } else {
                    return Err(format!("unknown option --{key}"));
                }
            } else if has_subcommand && out.subcommand.is_none() {
                out.subcommand = Some(arg.clone());
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| format!("--{name} expects an integer, got {v:?}")))
            .transpose()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| format!("--{name} expects an integer, got {v:?}")))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| format!("--{name} expects a number, got {v:?}")))
            .transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags_positionals() {
        let a = Args::parse(
            &argv("train --config cfg.json --epochs=100 --verbose data.mtx"),
            &["config", "epochs"],
            &["verbose"],
            true,
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.json"));
        assert_eq!(a.get_usize("epochs").unwrap(), Some(100));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["data.mtx"]);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(Args::parse(&argv("--nope"), &[], &[], false).is_err());
        assert!(Args::parse(&argv("--k"), &["k"], &[], false).is_err());
        assert!(Args::parse(&argv("--v=1"), &[], &["v"], false).is_err());
        let a = Args::parse(&argv("--n x"), &["n"], &[], false).unwrap();
        assert!(a.get_usize("n").is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(""), &["k"], &[], false).unwrap();
        assert_eq!(a.get_or("k", "d"), "d");
        assert_eq!(a.get_usize("k").unwrap(), None);
    }
}
