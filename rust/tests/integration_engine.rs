//! Cross-module integration for the execution engine: scheme → compiled
//! plan → JSON artifact → fleet assignment → batch-served traffic, checked
//! against the crossbar oracle end to end.

use autogmap::baselines::oracle::optimal_diagonal;
use autogmap::crossbar::cost::CostModel;
use autogmap::crossbar::place;
use autogmap::engine::{
    compile, synth_trace, AssignPolicy, BatchExecutor, ExecPlan, Fleet, TraceKind,
};
use autogmap::graph::{synth, GridSummary};
use autogmap::reorder::{reorder, Reordering};
use autogmap::scheme::{evaluate, RewardWeights, Scheme};
use std::sync::Arc;

fn qh882_workload() -> (autogmap::graph::Csr, GridSummary) {
    let m = synth::qh882_like(882);
    let r = reorder(&m, Reordering::CuthillMckee);
    let g = GridSummary::new(&r.matrix, 32);
    (r.matrix, g)
}

#[test]
fn compiled_full_block_plan_elides_and_serves_exactly() {
    let (m, g) = qh882_workload();
    let scheme = Scheme {
        diag_len: vec![g.n],
        fill_len: vec![],
    };
    // complete coverage by construction
    let e = evaluate(&scheme, &g, RewardWeights::new(0.8));
    assert_eq!(e.coverage_ratio, 1.0);

    let plan = compile(&m, &g, &scheme).unwrap();
    let arr = place(&m, &g, &scheme).unwrap();
    assert_eq!(plan.scheduled_tiles, arr.tiles.len());
    assert!(plan.elision_ratio() > 0.5, "elision {}", plan.elision_ratio());

    let exec = BatchExecutor::new(Arc::new(plan), 8);
    let trace = synth_trace(TraceKind::Bursty, g.dim, 64, 8, &[(0, g.dim)], 7);
    for batch in trace {
        let ys = exec.execute_batch(batch.clone());
        for (x, y) in batch.iter().zip(ys.iter()) {
            let want = arr.mvm(x);
            for (a, b) in y.iter().zip(want.iter()) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b}");
            }
        }
        // the optimized band-sharded multi-RHS mode answers identically
        let sharded = exec.execute_batch_sharded(batch);
        assert_eq!(ys, sharded, "sharded mode must be bit-identical");
        exec.recycle(ys);
        exec.recycle(sharded);
    }
}

#[test]
fn kernel_modes_and_artifacts_serve_identically_end_to_end() {
    // compile → force each kernel mix → v2 artifact round-trip → serve:
    // every path answers bit-identically to the auto-kernel plan.
    let (m, g) = qh882_workload();
    let scheme = Scheme {
        diag_len: vec![g.n],
        fill_len: vec![],
    };
    let plan = compile(&m, &g, &scheme).unwrap();
    let (dense_progs, sparse_progs) = plan.kernel_counts();
    assert_eq!(dense_progs + sparse_progs, plan.num_programs());
    assert!(sparse_progs > 0, "qh882 full-block tiles are sparse-dominated");
    let trace = synth_trace(TraceKind::Uniform, g.dim, 24, 6, &[(0, g.dim)], 3);
    let want: Vec<Vec<Vec<f64>>> = trace
        .iter()
        .map(|batch| batch.iter().map(|x| plan.mvm(x)).collect())
        .collect();
    let dir = std::env::temp_dir().join("autogmap_it_engine_kernels");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("qh882_v2_plan.json");
    plan.save(&path).unwrap();
    let loaded = ExecPlan::load(&path).unwrap();
    assert_eq!(plan, loaded);
    let mut dense = plan.clone();
    dense.rekernel(0.0);
    let mut sparse = plan.clone();
    sparse.rekernel(f64::INFINITY);
    for variant in [loaded, dense, sparse] {
        let exec = BatchExecutor::new(Arc::new(variant), 4);
        for (batch, w) in trace.iter().zip(want.iter()) {
            assert_eq!(&exec.execute_batch(batch.clone()), w);
            assert_eq!(&exec.execute_batch_sharded(batch.clone()), w);
        }
    }
}

#[test]
fn plan_artifact_roundtrips_and_serves_identically() {
    let (m, g) = qh882_workload();
    let scheme = optimal_diagonal(&g).expect("DP oracle partition");
    let plan = compile(&m, &g, &scheme).unwrap();

    let dir = std::env::temp_dir().join("autogmap_it_engine");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("qh882_plan.json");
    plan.save(&path).unwrap();
    let loaded = ExecPlan::load(&path).unwrap();
    assert_eq!(plan, loaded);

    // the deployed artifact answers exactly like the freshly compiled plan
    let x: Vec<f64> = (0..g.dim).map(|i| ((i * 29) % 13) as f64 - 6.0).collect();
    assert_eq!(plan.mvm(&x), loaded.mvm(&x));

    // and both match the oracle on the complete-coverage scheme
    let arr = place(&m, &g, &scheme).unwrap();
    let want = arr.mvm(&x);
    for (a, b) in loaded.mvm(&x).iter().zip(want.iter()) {
        assert!((a - b).abs() < 1e-9);
    }
}

#[test]
fn fleet_accounting_is_conserved_across_policies_and_sizes() {
    let (m, g) = qh882_workload();
    let scheme = Scheme {
        diag_len: vec![g.n],
        fill_len: vec![],
    };
    let plan = compile(&m, &g, &scheme).unwrap();
    let cost = CostModel::default();
    let total_cells = plan.cells();
    for banks in [1usize, 2, 8] {
        for policy in [AssignPolicy::RoundRobin, AssignPolicy::BalancedNnz] {
            let fleet = Fleet::assign(&plan, banks, policy).unwrap();
            assert_eq!(fleet.loads.len(), banks);
            let cells: u64 = fleet.loads.iter().map(|l| l.cells).sum();
            assert_eq!(cells, total_cells, "{policy:?}@{banks} lost cells");
            let tiles: usize = fleet.loads.iter().map(|l| l.tiles).sum();
            assert_eq!(tiles, plan.tiles.len());
            // energy is policy-independent (same tiles, different homes)
            let energy = fleet.mvm_energy_pj(&cost);
            let single = Fleet::assign(&plan, 1, AssignPolicy::RoundRobin)
                .unwrap()
                .mvm_energy_pj(&cost);
            assert!((energy - single).abs() < 1e-6 * single.max(1.0));
        }
    }
    // more banks never increase the modelled fleet latency
    let mut serial = cost;
    serial.parallel_tiles = 1;
    let l1 = Fleet::assign(&plan, 1, AssignPolicy::BalancedNnz)
        .unwrap()
        .mvm_latency_ns(&serial);
    let l8 = Fleet::assign(&plan, 8, AssignPolicy::BalancedNnz)
        .unwrap()
        .mvm_latency_ns(&serial);
    assert!(l8 <= l1);
}

#[test]
fn batch_graph_traffic_over_a_supermatrix_plan() {
    // block-diagonal batch supermatrix served with per-sub-graph requests:
    // the engine must dedup the repeated sub-graph programmings and still
    // answer exactly.
    let sub = synth::qm7_like(5828);
    let m = synth::batch_supermatrix(&[sub.clone(), sub.clone(), sub.clone(), sub]);
    let g = GridSummary::new(&m, 22);
    let scheme = Scheme {
        diag_len: vec![1; g.n],
        fill_len: vec![0; g.n - 1],
    };
    let plan = compile(&m, &g, &scheme).unwrap();
    assert_eq!(plan.tiles.len(), 4);
    assert_eq!(plan.num_programs(), 1, "identical sub-graphs must share programs");

    let arr = place(&m, &g, &scheme).unwrap();
    let segments: Vec<(usize, usize)> = (0..4).map(|i| (i * 22, (i + 1) * 22)).collect();
    let exec = BatchExecutor::new(Arc::new(plan), 4);
    let trace = synth_trace(TraceKind::BatchGraph, 88, 48, 6, &segments, 11);
    for batch in trace {
        let ys = exec.execute_batch(batch.clone());
        for (x, y) in batch.iter().zip(ys.iter()) {
            let want = arr.mvm(x);
            for (a, b) in y.iter().zip(want.iter()) {
                assert!((a - b).abs() < 1e-9);
            }
        }
        exec.recycle(ys);
    }
}
