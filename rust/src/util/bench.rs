//! Micro-benchmark harness (no `criterion` in the vendored crate set).
//!
//! Measures wall-clock of a closure with warmup, adaptive iteration count,
//! and robust statistics (median + MAD + mean ± stddev), printing one line
//! per benchmark in a stable, grep-friendly format:
//!
//! `bench <name> ... median 1.234 us  (mean 1.240 ± 0.02, n=4096)`
//!
//! Used by every target under `rust/benches/`.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    /// Median time per iteration, seconds.
    pub median_s: f64,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub iters: u64,
    pub samples: usize,
}

impl BenchStats {
    pub fn report_line(&self) -> String {
        format!(
            "bench {:<44} median {:>12}  (mean {} ± {}, min {}, n={}x{})",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.stddev_s),
            fmt_time(self.min_s),
            self.samples,
            self.iters,
        )
    }
}

/// Human-readable time.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner with shared config for a bench binary.
pub struct Bencher {
    /// Target time to spend per benchmark measuring (after warmup).
    pub measure_time: Duration,
    pub warmup_time: Duration,
    /// Number of measured samples to split the budget into.
    pub samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Honor AUTOGMAP_BENCH_FAST=1 for CI smoke runs.
        let fast = std::env::var("AUTOGMAP_BENCH_FAST").is_ok_and(|v| v == "1");
        Bencher {
            measure_time: Duration::from_millis(if fast { 200 } else { 1500 }),
            warmup_time: Duration::from_millis(if fast { 50 } else { 300 }),
            samples: if fast { 10 } else { 30 },
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run one benchmark. The closure is invoked repeatedly; its return
    /// value is black-boxed to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchStats {
        // Warmup + estimate cost of one call.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // Choose iterations per sample so a sample takes measure_time/samples.
        let sample_budget = self.measure_time.as_secs_f64() / self.samples as f64;
        let iters = ((sample_budget / per_call.max(1e-9)) as u64).clamp(1, 10_000_000);

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            times.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            median_s: median,
            mean_s: mean,
            stddev_s: var.sqrt(),
            min_s: times[0],
            max_s: *times.last().unwrap(),
            iters,
            samples: times.len(),
        };
        println!("{}", stats.report_line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All results so far (for throughput summaries at the end of a bench binary).
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

/// Opaque identity function the optimizer cannot see through.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Nearest-rank percentile of a sample set; `p` in [0, 100]. Sorts a copy,
/// so callers can keep their samples in arrival order.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(
        !samples.is_empty() && (0.0..=100.0).contains(&p),
        "percentile needs samples and p in [0,100]"
    );
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1) - 1]
}

/// Write a machine-readable benchmark artifact (the `BENCH_*.json`
/// convention: one flat JSON object per bench target, committed metrics
/// only — so successive PRs can diff the perf trajectory).
pub fn write_bench_json(
    path: &std::path::Path,
    fields: Vec<(&str, crate::util::json::Json)>,
) -> std::io::Result<()> {
    std::fs::write(path, crate::util::json::obj(fields).to_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            samples: 5,
            results: Vec::new(),
        };
        let stats = b.bench("noop_sum", || (0..100u64).sum::<u64>()).clone();
        assert!(stats.median_s > 0.0);
        assert!(stats.median_s < 1e-3);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 90.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn bench_json_artifact_roundtrips() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir().join("autogmap_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        write_bench_json(
            &path,
            vec![
                ("throughput_rps", Json::Num(1234.5)),
                ("p50_ms", Json::Num(0.8)),
            ],
        )
        .unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("throughput_rps").as_f64(), Some(1234.5));
        assert_eq!(doc.get("p50_ms").as_f64(), Some(0.8));
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
