//! The RL agent driver: REINFORCE-with-baseline training loop (Algo. 2/3)
//! executed against the AOT artifacts.
//!
//! Per epoch the coordinator makes exactly two PJRT calls:
//!   1. `rollout_<cfg>` — samples a batch of B episodes on-device;
//!   2. `train_<cfg>`   — teacher-forced REINFORCE + Adam update on-device;
//! everything between (scheme parsing, the environment reward, the EMA
//! baseline) is plain Rust on the grid prefix sums.

pub mod complexity;
pub mod lstm;
pub mod params;

use crate::graph::GridSummary;
use crate::runtime::manifest::ControllerEntry;
use crate::runtime::{literal, Executable, Runtime};
use crate::scheme::{evaluate, parse_actions, EvalResult, FillRule, RewardWeights, Scheme};
use crate::util::rng::Pcg64;
use anyhow::{ensure, Context, Result};
use params::{AdamState, Params};
use std::sync::Arc;

/// Training hyper-parameters (paper defaults where stated).
#[derive(Clone, Copy, Debug)]
pub struct TrainOptions {
    pub lr: f32,
    /// entropy bonus; 0 reproduces the paper exactly.
    pub ent_coef: f32,
    /// EMA decay of the reward baseline (Algo. 2 line 1).
    pub baseline_decay: f64,
    /// scalarization weights (Eq. 21).
    pub weights: RewardWeights,
    /// fill geometry rule (must agree with the artifact's fill_classes).
    pub fill_rule: FillRule,
    pub seed: u64,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            lr: 0.01,
            ent_coef: 0.0,
            baseline_decay: 0.95,
            weights: RewardWeights::new(0.8),
            fill_rule: FillRule::None,
            seed: 0,
        }
    }
}

/// Per-epoch statistics, logged by the coordinator.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub mean_reward: f64,
    pub max_reward: f64,
    pub mean_coverage: f64,
    pub mean_area: f64,
    /// fraction of the batch reaching complete coverage
    pub frac_complete: f64,
    pub baseline: f64,
    pub loss: f32,
    pub mean_logp: f32,
}

/// Best-so-far complete-coverage solution.
#[derive(Clone, Debug)]
pub struct BestSolution {
    pub scheme: Scheme,
    pub eval: EvalResult,
    pub epoch: usize,
}

/// REINFORCE trainer bound to one controller config + one matrix.
pub struct Trainer {
    pub entry: ControllerEntry,
    rollout_exe: Arc<Executable>,
    train_exe: Arc<Executable>,
    greedy_exe: Option<Arc<Executable>>,
    pub params: Params,
    pub opt: AdamState,
    /// Cached literal forms of params/m/v, reused as artifact inputs and
    /// refreshed in-place from the train step's *output* literals — avoids
    /// two Vec<f32> ↔ Literal conversions per epoch (EXPERIMENTS.md §Perf).
    lits: Option<(Vec<xla::Literal>, Vec<xla::Literal>, Vec<xla::Literal>)>,
    pub baseline: f64,
    baseline_init: bool,
    rng: Pcg64,
    pub opts: TrainOptions,
    /// best *complete-coverage* solution by area (the paper's deployable pick)
    pub best: Option<BestSolution>,
    /// best solution by scalarized reward regardless of coverage (what the
    /// paper's diagonal-only Table II rows report, e.g. C=0.875 A=0.438)
    pub best_reward: Option<BestSolution>,
    pub epoch: usize,
}

impl Trainer {
    pub fn new(rt: &Runtime, entry: ControllerEntry, opts: TrainOptions) -> Result<Trainer> {
        validate_fill_rule(&entry, &opts.fill_rule)?;
        let rollout_exe = rt.load(entry.artifact("rollout")?)?;
        let train_exe = rt.load(entry.artifact("train")?)?;
        let greedy_exe = entry
            .artifacts
            .get("greedy")
            .map(|f| rt.load(f))
            .transpose()?;
        let params = params::init_params(&entry, opts.seed);
        let opt = AdamState::new(&entry);
        Ok(Trainer {
            rng: Pcg64::seed_from_u64(opts.seed ^ 0x6167_656e_7400_0001),
            entry,
            rollout_exe,
            train_exe,
            greedy_exe,
            params,
            opt,
            lits: None,
            baseline: 0.0,
            baseline_init: false,
            opts,
            best: None,
            best_reward: None,
            epoch: 0,
        })
    }

    /// Restore params/opt/baseline from a checkpoint file.
    pub fn restore(&mut self, path: &std::path::Path) -> Result<()> {
        let (p, o, epoch, baseline) = params::load_checkpoint(path, &self.entry)?;
        self.params = p;
        self.opt = o;
        self.lits = None; // invalidate cached literals
        self.epoch = epoch;
        self.baseline = baseline;
        self.baseline_init = true;
        Ok(())
    }

    /// Refresh the host-side Adam state from the cached device literals —
    /// required before checkpointing (the hot loop keeps m/v only as
    /// literals).
    pub fn sync_host(&mut self) -> Result<()> {
        if let Some((_, m_lits, v_lits)) = self.lits.as_ref() {
            self.opt.m = params::from_literals(&self.entry, m_lits)?;
            self.opt.v = params::from_literals(&self.entry, v_lits)?;
        }
        Ok(())
    }

    /// One REINFORCE epoch (Algo. 3 lines 2-8). Returns batch statistics.
    pub fn epoch(&mut self, grid: &GridSummary) -> Result<EpochStats> {
        let (b, t) = (self.entry.batch, self.entry.steps);
        ensure!(
            grid.n == self.entry.n,
            "grid has {} cells but config {} expects {}",
            grid.n,
            self.entry.name,
            self.entry.n
        );

        // --- sample B episodes on-device (param literals cached across epochs)
        if self.lits.is_none() {
            self.lits = Some((
                params::to_literals(&self.entry, &self.params)?,
                params::to_literals(&self.entry, &self.opt.m)?,
                params::to_literals(&self.entry, &self.opt.v)?,
            ));
        }
        let (p_lits, _, _) = self.lits.as_ref().unwrap();
        let key = [self.rng.next_u32(), self.rng.next_u32()];
        let mut inputs: Vec<&xla::Literal> = p_lits.iter().collect();
        let key_lit = literal::lit_u32_1d(&key);
        inputs.push(&key_lit);
        let outs = self.rollout_exe.run_refs(&inputs)?;
        ensure!(outs.len() == 4, "rollout returned {} outputs", outs.len());
        let d_all = literal::to_vec_i32(&outs[0])?;
        let f_all = literal::to_vec_i32(&outs[1])?;
        ensure!(d_all.len() == b * t && f_all.len() == b * t);

        // --- environment: parse + evaluate each episode
        let evals = self.evaluate_batch(grid, &d_all, &f_all);
        let rewards: Vec<f64> = evals.iter().map(|e| e.reward).collect();
        let mean_reward = rewards.iter().sum::<f64>() / b as f64;
        let max_reward = rewards.iter().cloned().fold(f64::MIN, f64::max);

        // --- EMA baseline (Algo. 2 line 1)
        if !self.baseline_init {
            self.baseline = mean_reward;
            self.baseline_init = true;
        } else {
            self.baseline = self.opts.baseline_decay * self.baseline
                + (1.0 - self.opts.baseline_decay) * mean_reward;
        }
        let adv: Vec<f32> = rewards.iter().map(|r| (r - self.baseline) as f32).collect();

        // --- track the best complete-coverage and best-reward solutions
        for (i, e) in evals.iter().enumerate() {
            if e.coverage_ratio >= 1.0 {
                let better = match &self.best {
                    None => true,
                    Some(bst) => e.covered_area_units < bst.eval.covered_area_units,
                };
                if better {
                    let scheme = self.parse_episode(grid, &d_all, &f_all, i);
                    self.best = Some(BestSolution {
                        scheme,
                        eval: e.clone(),
                        epoch: self.epoch,
                    });
                }
            }
            let better_reward = match &self.best_reward {
                None => true,
                Some(bst) => e.reward > bst.eval.reward,
            };
            if better_reward {
                let scheme = self.parse_episode(grid, &d_all, &f_all, i);
                self.best_reward = Some(BestSolution {
                    scheme,
                    eval: e.clone(),
                    epoch: self.epoch,
                });
            }
        }

        // --- on-device REINFORCE + Adam step (inputs borrow the cached
        // literals; outputs *become* the next epoch's cached literals)
        let k = self.entry.params.len();
        let (p_lits, m_lits, v_lits) = self.lits.as_ref().unwrap();
        let t_lit = literal::lit_scalar_i32(self.opt.t);
        let d_lit = literal::lit_i32_2d(&d_all, b, t)?;
        let f_lit = literal::lit_i32_2d(&f_all, b, t)?;
        let adv_lit = literal::lit_f32_1d(&adv);
        let lr_lit = literal::lit_scalar_f32(self.opts.lr);
        let ent_lit = literal::lit_scalar_f32(self.opts.ent_coef);
        let mut tin: Vec<&xla::Literal> = Vec::with_capacity(3 * k + 6);
        tin.extend(p_lits.iter());
        tin.extend(m_lits.iter());
        tin.extend(v_lits.iter());
        tin.extend([&t_lit, &d_lit, &f_lit, &adv_lit, &lr_lit, &ent_lit]);
        let mut touts = self.train_exe.run_refs(&tin)?;
        ensure!(
            touts.len() == 3 * k + 3,
            "train returned {} outputs, expected {}",
            touts.len(),
            3 * k + 3
        );
        self.opt.t = touts[3 * k].to_vec::<i32>().context("adam t")?[0];
        let loss = touts[3 * k + 1].to_vec::<f32>().context("loss")?[0];
        let mean_logp = touts[3 * k + 2].to_vec::<f32>().context("mean_logp")?[0];
        touts.truncate(3 * k);
        let new_v: Vec<xla::Literal> = touts.split_off(2 * k);
        let new_m: Vec<xla::Literal> = touts.split_off(k);
        // keep the cheap Vec<f32> mirror in sync for checkpoints/inspection
        self.params = params::from_literals(&self.entry, &touts)?;
        self.lits = Some((touts, new_m, new_v));

        let stats = EpochStats {
            epoch: self.epoch,
            mean_reward,
            max_reward,
            mean_coverage: evals.iter().map(|e| e.coverage_ratio).sum::<f64>() / b as f64,
            mean_area: evals.iter().map(|e| e.area_ratio).sum::<f64>() / b as f64,
            frac_complete: evals.iter().filter(|e| e.coverage_ratio >= 1.0).count() as f64
                / b as f64,
            baseline: self.baseline,
            loss,
            mean_logp,
        };
        self.epoch += 1;
        Ok(stats)
    }

    /// Deterministic greedy decode with the current parameters.
    pub fn greedy(&self, grid: &GridSummary) -> Result<(Scheme, EvalResult)> {
        let exe = self
            .greedy_exe
            .as_ref()
            .context("no greedy artifact for this config")?;
        let inputs = params::to_literals(&self.entry, &self.params)?;
        let outs = exe.run(&inputs)?;
        let d_all = literal::to_vec_i32(&outs[0])?;
        let f_all = literal::to_vec_i32(&outs[1])?;
        let scheme = self.parse_episode(grid, &d_all, &f_all, 0);
        let eval = evaluate(&scheme, grid, self.opts.weights);
        Ok((scheme, eval))
    }

    fn parse_episode(
        &self,
        grid: &GridSummary,
        d_all: &[i32],
        f_all: &[i32],
        i: usize,
    ) -> Scheme {
        let t = self.entry.steps;
        let d: Vec<u8> = d_all[i * t..(i + 1) * t].iter().map(|&x| x as u8).collect();
        let f: Vec<usize> = f_all[i * t..(i + 1) * t]
            .iter()
            .map(|&x| x as usize)
            .collect();
        parse_actions(grid.n, &d, &f, self.opts.fill_rule)
    }

    fn evaluate_batch(
        &self,
        grid: &GridSummary,
        d_all: &[i32],
        f_all: &[i32],
    ) -> Vec<EvalResult> {
        (0..self.entry.batch)
            .map(|i| {
                let s = self.parse_episode(grid, d_all, f_all, i);
                evaluate(&s, grid, self.opts.weights)
            })
            .collect()
    }
}

/// The artifact's fill head and the Rust geometry rule must agree on the
/// number of classes.
pub fn validate_fill_rule(entry: &ControllerEntry, rule: &FillRule) -> Result<()> {
    let expected = rule.num_classes();
    ensure!(
        entry.fill_classes == expected,
        "config {} has {} fill classes but rule {:?} implies {}",
        entry.name,
        entry.fill_classes,
        rule,
        expected
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    #[test]
    fn fill_rule_mismatch_is_rejected() {
        let entry = ControllerEntry {
            name: "x".into(),
            n: 4,
            hidden: 2,
            fill_classes: 4,
            batch: 1,
            bilstm: false,
            steps: 3,
            params: vec![ParamSpec { name: "x0".into(), shape: vec![2] }],
            artifacts: Default::default(),
        };
        assert!(validate_fill_rule(&entry, &FillRule::None).is_err());
        assert!(validate_fill_rule(&entry, &FillRule::Fixed { size: 1 }).is_err());
        assert!(validate_fill_rule(&entry, &FillRule::Dynamic { grades: 4 }).is_ok());
    }
}
