//! The `fault-bench` chaos driver: inject device faults mid-stream under
//! concurrent socket clients and prove the serving stack never returns a
//! wrong answer.
//!
//! The run builds an R-MAT deployment, registers it in a fault-armed
//! [`DeploymentRegistry`], starts an in-process [`NetServer`], and drives
//! three phases of concurrent TCP clients:
//!
//! 1. **Pre-fault**: every response must bit-match `Deployment::mvm` on
//!    the healthy plan (the zero-fault contract), measuring baseline
//!    nnz/s throughput.
//! 2. **Chaos**: once the clients are streaming, a control connection
//!    issues `{"admin":{"inject":..}}` to corrupt one bank, then keeps
//!    probing until the harness detects and degrades (detection latency).
//!    Every element of every response in this phase — including the
//!    window between injection and detection — must carry either the
//!    healthy plan's bits or the host-CSR oracle's bits
//!    ([`crate::api::Deployment::mvm_oracle`]). Anything else is an
//!    escaped wrong answer and fails the run; `escaped_wrong_answers` in
//!    the ledger is therefore 0 by construction or the bench errors. The
//!    control thread also asserts that **every** program the injection
//!    corrupted ends up quarantined (100% detection coverage).
//! 3. **Post-repair**: the control connection issues
//!    `{"admin":{"repair":..}}` (repair latency), then the clients run
//!    again; responses must be undegraded and bit-identical to the
//!    healthy plan, and throughput is compared against phase 1
//!    (`recovery_ratio`).
//!
//! The ledger lands in `BENCH_fault.json`; the CI `fault-smoke` job greps
//! it for the detection/repair/recovery fields.

use crate::api::{Deployment, DeploymentBuilder, Error, Result, Source, Strategy};
use crate::fault::{FaultHarness, FaultOptions};
use crate::graph::synth;
use crate::net::{DeploymentRegistry, NetOptions, NetServer, RegistryOptions};
use crate::util::bench::write_bench_json;
use crate::util::json::{num_arr, obj, Json};
use crate::util::rng::Pcg64;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The tenant id the bench registers its deployment under.
const TENANT: &str = "g";

/// Configuration for one chaos run.
#[derive(Clone, Debug)]
pub struct FaultBenchOptions {
    /// R-MAT node count (`AUTOGMAP_BENCH_FAST=1` caps it at 2000)
    pub nodes: usize,
    /// average edges per node (nnz ≈ nodes × degree)
    pub degree: usize,
    /// grid summary resolution the mapper works at
    pub grid: usize,
    /// crossbar banks the fleet spreads tiles over (≥ 2 so repair has a
    /// healthy bank to re-program onto)
    pub banks: usize,
    /// shared-pool worker threads
    pub workers: usize,
    /// per-tenant admission queue depth
    pub queue_depth: usize,
    /// concurrent client connections (floored at 2 — the fault must land
    /// mid-stream under real concurrency)
    pub clients: usize,
    /// requests per client per phase
    pub requests: usize,
    /// which bank the injected fault hits
    pub fault_bank: usize,
    /// fault kind: `stuck0`, `stuck1`, `drift`, or `outage`
    pub fault_kind: String,
    /// kind-specific rate (cell fraction for stuck-at, sigma for drift)
    pub fault_rate: f64,
    /// fault-model rng seed
    pub fault_seed: u64,
    /// scrub cadence forwarded to [`FaultOptions`]
    pub scrub_every: u64,
    /// request-vector rng seed
    pub seed: u64,
    /// listen address; `127.0.0.1:0` picks a free port
    pub listen: String,
    /// where to write the machine-readable ledger
    pub bench_json: PathBuf,
    /// fail the run when post-repair throughput drops below 90% of the
    /// pre-fault baseline (off by default: wall-clock ratios are noisy on
    /// shared CI machines; the ledger records the ratio regardless)
    pub assert_recovery: bool,
}

impl Default for FaultBenchOptions {
    fn default() -> FaultBenchOptions {
        FaultBenchOptions {
            nodes: 2000,
            degree: 8,
            grid: 32,
            banks: 4,
            workers: 4,
            queue_depth: 32,
            clients: 2,
            requests: 120,
            fault_bank: 0,
            fault_kind: "outage".into(),
            fault_rate: 0.05,
            fault_seed: 0xfa017,
            scrub_every: 256,
            seed: 0x5eed,
            listen: "127.0.0.1:0".into(),
            bench_json: PathBuf::from("BENCH_fault.json"),
            assert_recovery: false,
        }
    }
}

/// What a finished chaos run measured. A report is only returned when
/// every response survived the plan-or-oracle bit check — an escaped
/// wrong answer is an `Err`, not a statistic.
#[derive(Clone, Debug)]
pub struct FaultBenchReport {
    pub served: u64,
    pub degraded_responses: u64,
    pub injected_cells: u64,
    pub detection_ms: f64,
    pub repair_ms: f64,
    pub pre_fault_nnz_per_s: f64,
    pub degraded_nnz_per_s: f64,
    pub post_repair_nnz_per_s: f64,
    pub recovery_ratio: f64,
    pub wall_s: f64,
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Conn {
    fn connect(addr: SocketAddr) -> std::result::Result<Conn, String> {
        let s = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let r = s.try_clone().map_err(|e| format!("clone stream: {e}"))?;
        Ok(Conn {
            reader: BufReader::new(r),
            writer: BufWriter::new(s),
        })
    }

    fn roundtrip(&mut self, line: &str) -> std::result::Result<Json, String> {
        writeln!(self.writer, "{line}").map_err(|e| format!("send: {e}"))?;
        self.writer.flush().map_err(|e| format!("flush: {e}"))?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf).map_err(|e| format!("recv: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-request (dropped response)".into());
        }
        Json::parse(buf.trim()).map_err(|e| format!("bad response JSON: {e}"))
    }
}

/// Pull `y` and the `degraded` flag out of a response, or say why not.
fn parse_answer(resp: &Json) -> std::result::Result<(Vec<f64>, bool), String> {
    if resp.get("error") != &Json::Null {
        return Err(format!("error response: {}", resp.get("error").to_string()));
    }
    let y: Vec<f64> = resp
        .get("y")
        .as_arr()
        .ok_or_else(|| format!("response carries no \"y\": {}", resp.to_string()))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "non-numeric element in y".to_string()))
        .collect::<std::result::Result<_, _>>()?;
    Ok((y, resp.get("degraded").as_bool() == Some(true)))
}

/// The plan-or-oracle bit check: under faults, every element must carry
/// either the healthy plan's bits or the host-CSR oracle's bits. In
/// `strict` mode (healthy phases) the whole vector must bit-match the
/// plan and the response must not be flagged degraded.
fn check_answer(
    got: &[f64],
    degraded: bool,
    want: &[f64],
    oracle: &[f64],
    strict: bool,
) -> std::result::Result<(), String> {
    if strict {
        if degraded {
            return Err("response flagged degraded in a healthy phase".into());
        }
        if got != want {
            return Err("response does not bit-match the healthy Deployment::mvm".into());
        }
        return Ok(());
    }
    if got.len() != want.len() {
        return Err(format!("answer length {} != dim {}", got.len(), want.len()));
    }
    for (i, &g) in got.iter().enumerate() {
        if g.to_bits() != want[i].to_bits() && g.to_bits() != oracle[i].to_bits() {
            return Err(format!(
                "ESCAPED WRONG ANSWER at row {i}: {g} is neither the plan's {} nor \
                 the oracle's {}",
                want[i], oracle[i]
            ));
        }
    }
    Ok(())
}

/// One phase of concurrent clients: `clients` connections, `requests`
/// verified MVMs each. Returns (served, degraded responses, wall seconds).
fn run_phase(
    addr: SocketAddr,
    dep: &Arc<Deployment>,
    clients: usize,
    requests: usize,
    seed: u64,
    strict: bool,
    tag: &'static str,
) -> Result<(u64, u64, f64)> {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let dep = dep.clone();
        let handle = std::thread::spawn(move || -> std::result::Result<(u64, u64), String> {
            let dim = dep.provenance.dim;
            let mut conn = Conn::connect(addr)?;
            let mut rng = Pcg64::new(seed, c as u64);
            let mut served = 0u64;
            let mut degraded_seen = 0u64;
            for r in 0..requests {
                let x: Vec<f64> = (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
                let want = dep.mvm(&x).map_err(|e| format!("plan oracle mvm: {e}"))?;
                let oracle =
                    dep.mvm_oracle(&x).map_err(|e| format!("digital oracle mvm: {e}"))?;
                let req = obj(vec![
                    ("tenant", Json::Str(TENANT.into())),
                    ("id", Json::Num(r as f64)),
                    ("x", num_arr(x)),
                ]);
                let resp = conn.roundtrip(&req.to_string())?;
                let (got, degraded) =
                    parse_answer(&resp).map_err(|e| format!("{tag} client {c} req {r}: {e}"))?;
                check_answer(&got, degraded, &want, &oracle, strict)
                    .map_err(|e| format!("{tag} client {c} req {r}: {e}"))?;
                served += 1;
                degraded_seen += degraded as u64;
            }
            Ok((served, degraded_seen))
        });
        handles.push(handle);
    }
    let mut served = 0u64;
    let mut degraded = 0u64;
    let mut failures: Vec<String> = Vec::new();
    for h in handles {
        match h.join() {
            Ok(Ok((s, d))) => {
                served += s;
                degraded += d;
            }
            Ok(Err(e)) => failures.push(e),
            Err(_) => failures.push(format!("{tag} client thread panicked")),
        }
    }
    if !failures.is_empty() {
        return Err(Error::Validate(format!(
            "{} of {clients} {tag} clients failed; first: {}",
            failures.len(),
            failures[0]
        )));
    }
    Ok((served, degraded, t0.elapsed().as_secs_f64()))
}

/// Run the chaos bench (see module docs). Returns the aggregate report
/// and writes `BENCH_fault.json`; any correctness violation — an escaped
/// wrong answer, a missed program, a failed repair — is an error.
pub fn run_fault_bench(opts: &FaultBenchOptions) -> Result<FaultBenchReport> {
    let fast = std::env::var("AUTOGMAP_BENCH_FAST").is_ok_and(|v| v == "1");
    let nodes = if fast { opts.nodes.min(2000) } else { opts.nodes }.max(16);
    let target_nnz = ((nodes * opts.degree.max(1)) / 2).max(1) * 2;
    let clients = opts.clients.max(2);
    let requests = opts.requests.max(1);
    if opts.banks < 2 {
        return Err(Error::Validate(
            "fault-bench needs --banks >= 2 so repair has a healthy bank left".into(),
        ));
    }
    let t0 = Instant::now();

    let matrix = synth::rmat_like(nodes, target_nnz, opts.seed);
    let built = DeploymentBuilder::new(
        Source::Matrix {
            label: format!("rmat{nodes}"),
            matrix,
        },
        Strategy::FixedBlock { block: 2 },
    )
    .grid(opts.grid.max(2))
    .banks(opts.banks)
    .workers(opts.workers)
    .build()?;

    let registry = Arc::new(DeploymentRegistry::new(&RegistryOptions {
        workers: opts.workers,
        queue_depth: opts.queue_depth.max(clients + 1),
        sharded: true,
        fault: Some(FaultOptions {
            scrub_every: opts.scrub_every,
            ..FaultOptions::default()
        }),
        remap_after: 0,
    }));
    registry.insert(TENANT, built, None);
    let entry = registry.get(TENANT)?.entry();
    let dep: Arc<Deployment> = entry.deployment().clone();
    let harness: Arc<FaultHarness> = entry
        .fault_harness()
        .cloned()
        .ok_or_else(|| Error::Validate("registry did not arm the fault harness".into()))?;
    let nnz = entry.nnz();
    let dim = entry.dim();

    let server = NetServer::start(registry.clone(), &opts.listen, &NetOptions::default())?;
    let addr = server.addr();

    // phase 1 — pre-fault baseline: strict bit-identity, no degradation
    let (served_pre, _, wall_pre) =
        run_phase(addr, &dep, clients, requests, opts.seed, true, "pre-fault")?;
    let pre_nnz_per_s = served_pre as f64 * nnz as f64 / wall_pre.max(1e-9);

    // phase 2 — chaos: clients stream while the control connection
    // injects and then watches for detection
    let mut control = Conn::connect(addr).map_err(Error::Validate)?;
    let chaos_seed = opts.seed ^ 0x6368_616f_73; // distinct request vectors
    let dep2 = dep.clone();
    let chaos = std::thread::spawn(move || {
        run_phase(addr, &dep2, clients, requests, chaos_seed, false, "chaos")
    });

    let inject_line = obj(vec![(
        "admin",
        obj(vec![(
            "inject",
            obj(vec![
                ("id", Json::Str(TENANT.into())),
                ("bank", Json::Num(opts.fault_bank as f64)),
                ("kind", Json::Str(opts.fault_kind.clone())),
                ("rate", Json::Num(opts.fault_rate)),
                ("seed", Json::Num(opts.fault_seed as f64)),
            ]),
        )]),
    )])
    .to_string();
    let t_inject = Instant::now();
    let ack = control.roundtrip(&inject_line).map_err(Error::Validate)?;
    if ack.get("admin").as_str() != Some("inject") {
        return Err(Error::Validate(format!(
            "inject rejected: {}",
            ack.to_string()
        )));
    }
    let injected_cells = ack.get("cells_changed").as_i64().unwrap_or(0).max(0) as u64;
    let injected_programs: Vec<usize> = ack
        .get("programs")
        .as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_i64()).map(|p| p as usize).collect())
        .unwrap_or_default();
    if injected_cells == 0 || injected_programs.is_empty() {
        return Err(Error::Validate(format!(
            "fault on bank {} corrupted nothing (kind {}, rate {}); pick a mapped bank \
             or a higher rate",
            opts.fault_bank, opts.fault_kind, opts.fault_rate
        )));
    }

    // detection: the control connection keeps serving verified probes (so
    // detection cannot starve even if the chaos clients finish early) and
    // polls admin stats until the harness reports itself degraded
    let mut probe_rng = Pcg64::new(opts.seed ^ 0x6465_7465_6374, 0xc0);
    let detection_ms = loop {
        let x: Vec<f64> = (0..dim).map(|_| probe_rng.uniform(-2.0, 2.0)).collect();
        let want = dep.mvm(&x)?;
        let oracle = dep.mvm_oracle(&x)?;
        let req = obj(vec![
            ("tenant", Json::Str(TENANT.into())),
            ("id", Json::Str("detect-probe".into())),
            ("x", num_arr(x)),
        ]);
        let resp = control.roundtrip(&req.to_string()).map_err(Error::Validate)?;
        let (got, degraded) = parse_answer(&resp).map_err(Error::Validate)?;
        check_answer(&got, degraded, &want, &oracle, false).map_err(Error::Validate)?;
        let stats = control
            .roundtrip(r#"{"admin":"stats"}"#)
            .map_err(Error::Validate)?;
        let health = stats.get("stats").get(TENANT).get("health").clone();
        if health.get("degraded").as_bool() == Some(true) {
            break t_inject.elapsed().as_secs_f64() * 1e3;
        }
        if t_inject.elapsed() > Duration::from_secs(30) {
            return Err(Error::Validate(
                "fault was never detected within 30s of injection".into(),
            ));
        }
    };

    // 100% detection coverage: every program the injection corrupted must
    // be quarantined (the harness may legitimately quarantine more — all
    // programs on the failed bank's tiles)
    let quarantined = harness.current_epoch().quarantined_programs.clone();
    let missed: Vec<usize> = injected_programs
        .iter()
        .copied()
        .filter(|p| !quarantined.contains(p))
        .collect();
    if !missed.is_empty() {
        return Err(Error::Validate(format!(
            "detection missed {} of {} corrupted programs: {missed:?}",
            missed.len(),
            injected_programs.len()
        )));
    }

    let (served_chaos, degraded_responses, wall_chaos) = chaos
        .join()
        .map_err(|_| Error::Validate("chaos phase driver panicked".into()))??;
    let degraded_nnz_per_s = served_chaos as f64 * nnz as f64 / wall_chaos.max(1e-9);

    // repair: re-program onto healthy banks, then prove restored identity
    let repair_line = obj(vec![(
        "admin",
        obj(vec![("repair", obj(vec![("id", Json::Str(TENANT.into()))]))]),
    )])
    .to_string();
    let t_repair = Instant::now();
    let ack = control.roundtrip(&repair_line).map_err(Error::Validate)?;
    let repair_ms = t_repair.elapsed().as_secs_f64() * 1e3;
    if ack.get("admin").as_str() != Some("repair") {
        return Err(Error::Validate(format!(
            "repair rejected: {}",
            ack.to_string()
        )));
    }
    let generation = ack.get("generation").as_i64().unwrap_or(0).max(0) as u64;
    drop(control);

    // phase 3 — post-repair: strict again, and throughput should recover
    let (served_post, _, wall_post) = run_phase(
        addr,
        &dep,
        clients,
        requests,
        opts.seed ^ 0x7265_7061_6972,
        true,
        "post-repair",
    )?;
    let post_nnz_per_s = served_post as f64 * nnz as f64 / wall_post.max(1e-9);
    let recovery_ratio = post_nnz_per_s / pre_nnz_per_s.max(1e-9);
    if opts.assert_recovery && recovery_ratio < 0.9 {
        return Err(Error::Validate(format!(
            "post-repair throughput recovered only {:.1}% of the pre-fault baseline",
            recovery_ratio * 100.0
        )));
    }

    let report = FaultBenchReport {
        served: served_pre + served_chaos + served_post,
        degraded_responses,
        injected_cells,
        detection_ms,
        repair_ms,
        pre_fault_nnz_per_s: pre_nnz_per_s,
        degraded_nnz_per_s,
        post_repair_nnz_per_s: post_nnz_per_s,
        recovery_ratio,
        wall_s: t0.elapsed().as_secs_f64(),
    };
    let health = harness.health();
    write_bench_json(
        &opts.bench_json,
        vec![
            ("bench", Json::Str("fault".into())),
            ("nodes", Json::Num(nodes as f64)),
            ("nnz", Json::Num(nnz as f64)),
            ("banks", Json::Num(opts.banks as f64)),
            ("workers", Json::Num(registry.workers() as f64)),
            ("clients", Json::Num(clients as f64)),
            ("requests_per_client", Json::Num(requests as f64)),
            (
                "fault",
                obj(vec![
                    ("bank", Json::Num(opts.fault_bank as f64)),
                    ("kind", Json::Str(opts.fault_kind.clone())),
                    ("rate", Json::Num(opts.fault_rate)),
                    ("seed", Json::Num(opts.fault_seed as f64)),
                ]),
            ),
            ("scrub_every", Json::Num(opts.scrub_every as f64)),
            ("injected_cells", Json::Num(report.injected_cells as f64)),
            ("injected_programs", Json::Num(injected_programs.len() as f64)),
            ("quarantined_programs", Json::Num(quarantined.len() as f64)),
            ("detected_all_programs", Json::Bool(true)),
            ("detection_ms", Json::Num(report.detection_ms)),
            ("repair_ms", Json::Num(report.repair_ms)),
            ("generation", Json::Num(generation as f64)),
            (
                "degraded_responses",
                Json::Num(report.degraded_responses as f64),
            ),
            ("escaped_wrong_answers", Json::Num(0.0)),
            ("pre_fault_nnz_per_s", Json::Num(report.pre_fault_nnz_per_s)),
            ("degraded_nnz_per_s", Json::Num(report.degraded_nnz_per_s)),
            (
                "post_repair_nnz_per_s",
                Json::Num(report.post_repair_nnz_per_s),
            ),
            ("recovery_ratio", Json::Num(report.recovery_ratio)),
            ("served", Json::Num(report.served as f64)),
            ("wall_s", Json::Num(report.wall_s)),
            ("health", crate::api::dispatch::health_json(&health)),
        ],
    )?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_run_detects_repairs_and_escapes_nothing() {
        let dir = std::env::temp_dir().join("autogmap_fault_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let opts = FaultBenchOptions {
            nodes: 300,
            degree: 6,
            grid: 8,
            banks: 3,
            workers: 2,
            clients: 2,
            requests: 40,
            fault_kind: "stuck0".into(),
            fault_rate: 0.4,
            bench_json: dir.join("BENCH_fault.json"),
            ..FaultBenchOptions::default()
        };
        let report = run_fault_bench(&opts).unwrap();
        // three phases of 2 clients × 40 requests; the control probes are
        // not counted in `served`
        assert_eq!(report.served, 2 * 40 * 3);
        assert!(report.injected_cells > 0);
        assert!(report.detection_ms >= 0.0);
        assert!(report.repair_ms >= 0.0);
        assert!(report.pre_fault_nnz_per_s > 0.0);
        assert!(report.post_repair_nnz_per_s > 0.0);
        let ledger = std::fs::read_to_string(&opts.bench_json).unwrap();
        let doc = Json::parse(&ledger).unwrap();
        assert_eq!(doc.get("bench").as_str(), Some("fault"));
        assert_eq!(doc.get("escaped_wrong_answers").as_i64(), Some(0));
        assert_eq!(doc.get("detected_all_programs").as_bool(), Some(true));
        assert_eq!(doc.get("health").get("repairs").as_i64(), Some(1));
        assert_eq!(doc.get("health").get("degraded").as_bool(), Some(false));
        assert!(doc.get("recovery_ratio").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn single_bank_fleets_are_rejected_up_front() {
        let opts = FaultBenchOptions {
            banks: 1,
            ..FaultBenchOptions::default()
        };
        let err = run_fault_bench(&opts).unwrap_err();
        assert_eq!(err.kind(), "validate");
    }
}
