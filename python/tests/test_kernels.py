"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; assert_allclose against ref.py
is the core correctness signal for everything the artifacts compute.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels.block_mvm import block_mvm
from compile.kernels.lstm_cell import lstm_cell
from compile.kernels.ref import block_mvm_ref, lstm_cell_ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# lstm_cell


@hypothesis.given(
    batch=st.integers(1, 16),
    inp=st.integers(1, 24),
    hidden=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_lstm_cell_matches_ref(batch, inp, hidden, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = rand(ks[0], (batch, inp))
    h = rand(ks[1], (batch, hidden))
    c = rand(ks[2], (batch, hidden))
    w = rand(ks[3], (inp + hidden, 4 * hidden), 0.5)
    b = rand(ks[4], (4 * hidden,), 0.5)
    h2, c2 = lstm_cell(x, h, c, w, b)
    hr, cr = lstm_cell_ref(x, h, c, w, b)
    assert_allclose(np.asarray(h2), np.asarray(hr), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(c2), np.asarray(cr), rtol=1e-5, atol=1e-6)


def test_lstm_cell_state_bounds():
    # h = o * tanh(c) is bounded in (-1, 1)
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = rand(ks[0], (8, 10), 10.0)
    h = rand(ks[1], (8, 10), 10.0)
    c = rand(ks[2], (8, 10), 10.0)
    w = rand(ks[3], (20, 40), 10.0)
    b = rand(ks[4], (40,), 10.0)
    h2, c2 = lstm_cell(x, h, c, w, b)
    assert np.all(np.abs(np.asarray(h2)) <= 1.0)
    assert np.all(np.isfinite(np.asarray(c2)))


def test_lstm_cell_zero_weights_decay():
    # zero weights/biases: f=i=o=sigmoid(0)=0.5, g=tanh(0)=0 -> c' = c/2
    b_ = jnp.zeros((12,))
    w = jnp.zeros((6, 12))
    x = jnp.ones((2, 3))
    h = jnp.ones((2, 3))
    c = jnp.ones((2, 3))
    h2, c2 = lstm_cell(x, h, c, w, b_)
    assert_allclose(np.asarray(c2), 0.5 * np.ones((2, 3)), rtol=1e-6)
    assert_allclose(np.asarray(h2), 0.5 * np.tanh(0.5) * np.ones((2, 3)), rtol=1e-6)


def test_lstm_cell_jit_and_scan_compose():
    # the exact composition used by the L2 scan must be traceable
    def step(carry, _):
        h, c = carry
        h, c = lstm_cell(h, h, c, w, b)
        return (h, c), h

    w = rand(jax.random.PRNGKey(1), (8, 16), 0.3)
    b = rand(jax.random.PRNGKey(2), (16,), 0.3)
    h0 = rand(jax.random.PRNGKey(3), (4, 4))
    c0 = jnp.zeros((4, 4))
    (_, _), hs = jax.jit(
        lambda h, c: jax.lax.scan(step, (h, c), None, length=5)
    )(h0, c0)
    assert hs.shape == (5, 4, 4)
    assert np.all(np.isfinite(np.asarray(hs)))


# ---------------------------------------------------------------------------
# block_mvm


@hypothesis.given(
    nb=st.integers(1, 12),
    k=st.integers(1, 16),
    nr=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_mvm_matches_ref(nb, k, nr, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    tiles = rand(ks[0], (nb, k, k))
    x = rand(ks[1], (nb, k))
    rows = jax.random.randint(ks[2], (nb,), 0, nr)
    onehot = jax.nn.one_hot(rows, nr, dtype=jnp.float32)
    out = block_mvm(tiles, x, onehot)
    ref = block_mvm_ref(tiles, x, onehot)
    assert out.shape == (nr, k)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_block_mvm_reconstructs_dense_spmv():
    # tiling a dense matrix into K-blocks and accumulating must equal A @ x
    rng = np.random.default_rng(0)
    dim, k = 12, 4
    a = rng.standard_normal((dim, dim)).astype(np.float32)
    x = rng.standard_normal(dim).astype(np.float32)
    nseg = dim // k
    tiles, xt, rows = [], [], []
    for ri in range(nseg):
        for ci in range(nseg):
            tiles.append(a[ri * k : (ri + 1) * k, ci * k : (ci + 1) * k])
            xt.append(x[ci * k : (ci + 1) * k])
            rows.append(ri)
    tiles = jnp.asarray(np.stack(tiles))
    xt = jnp.asarray(np.stack(xt))
    onehot = jax.nn.one_hot(jnp.asarray(rows), nseg, dtype=jnp.float32)
    out = np.asarray(block_mvm(tiles, xt, onehot)).reshape(-1)
    assert_allclose(out, a @ x, rtol=1e-4, atol=1e-5)


def test_block_mvm_zero_padding_tiles_are_noops():
    tiles = jnp.zeros((3, 4, 4))
    x = jnp.ones((3, 4))
    onehot = jax.nn.one_hot(jnp.asarray([0, 1, 1]), 2, dtype=jnp.float32)
    out = block_mvm(tiles, x, onehot)
    assert np.all(np.asarray(out) == 0.0)
