//! Plan compilation: `Scheme + Csr + GridSummary → ExecPlan`.
//!
//! [`crate::crossbar::place`] materializes every K×K tile of a scheme —
//! including tiles whose sub-block holds no non-zeros at all, which on a
//! 0.995-sparse qh882-like matrix is the vast majority of a large block's
//! interior. An [`ExecPlan`] is the deployable artifact a trained scheme
//! compiles into:
//!
//! - **zero-tile elision**: all-zero tiles are dropped from the schedule
//!   (they contribute exactly nothing to y' = A'x');
//! - **programming dedup**: tiles with bit-identical conductance blocks
//!   share one program buffer (block-diagonal batch supermatrices repeat
//!   whole sub-graphs);
//! - **clipped extents**: each tile records the rows×cols actually inside
//!   the matrix, so edge tiles (882 = 27·32 + 18) neither compute nor
//!   account for their zero-padded overhang;
//! - **JSON serialization**: plans save/load as standalone artifacts
//!   (manifest-style, [`crate::util::json`]), so a mapping trained once
//!   deploys without re-running placement.
//!
//! Executing a plan is bit-compatible with [`CrossbarArray::mvm`]
//! (`crate::crossbar::CrossbarArray::mvm`): tiles are scheduled in the
//! same scheme order and each row accumulates in the same element order,
//! so elision only removes exact zeros from the sums.

use crate::graph::{Csr, GridSummary};
use crate::scheme::{GridRect, Scheme};
use crate::util::json::{num_arr, obj, Json};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One scheduled tile: geometry plus a reference into the deduplicated
/// program table.
#[derive(Clone, Debug, PartialEq)]
pub struct TileSpec {
    /// top-left corner in matrix units
    pub row0: usize,
    pub col0: usize,
    /// clipped extents: rows×cols actually inside the matrix (≤ K each)
    pub rows: usize,
    pub cols: usize,
    /// index into [`ExecPlan::programs`]
    pub program: usize,
}

/// A compiled, servable mapping plan: the flat tile schedule of one scheme
/// with all-zero tiles elided and identical programmings shared.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecPlan {
    /// physical crossbar tile side K
    pub k: usize,
    /// matrix dimension D
    pub dim: usize,
    /// tile schedule, in scheme placement order
    pub tiles: Vec<TileSpec>,
    /// deduplicated conductance buffers; `programs[t.program]` is
    /// `t.rows × t.cols`, row-major with stride `t.cols`
    pub programs: Vec<Vec<f32>>,
    /// tiles the scheme demanded before elision
    pub scheduled_tiles: usize,
    /// all-zero tiles dropped from the schedule
    pub elided_tiles: usize,
}

/// Compile a scheme against a matrix into an executable plan.
///
/// Tile traversal order matches [`crate::crossbar::place`] exactly, so a
/// plan's MVM reproduces the oracle's accumulation order bit for bit.
pub fn compile(m: &Csr, g: &GridSummary, scheme: &Scheme) -> Result<ExecPlan> {
    scheme
        .validate(g.n)
        .map_err(|e| anyhow!("cannot compile invalid scheme: {e}"))?;
    compile_rects(m, g, &scheme.rects())
}

/// Compile an explicit (disjoint) rectangle schedule in grid coordinates —
/// the generalized core of [`compile`]. The mapper's composite mappings
/// produce clipped rectangles that are not expressible as one diagonal+fill
/// scheme; this entry point compiles them directly. Callers are responsible
/// for rectangle disjointness (overlapping rects would double-count nnz in
/// the MVM).
pub fn compile_rects(m: &Csr, g: &GridSummary, rects: &[GridRect]) -> Result<ExecPlan> {
    ensure!(
        m.rows == g.dim && m.cols == g.dim,
        "matrix/grid dimension mismatch"
    );
    let k = g.grid;
    let mut tiles = Vec::new();
    let mut programs: Vec<Vec<f32>> = Vec::new();
    let mut dedup: HashMap<Vec<u32>, usize> = HashMap::new();
    let mut scheduled = 0usize;
    let mut elided = 0usize;
    for rect in rects {
        ensure!(
            rect.r1 <= g.n && rect.c1 <= g.n,
            "rect {rect:?} exceeds the {}-cell grid",
            g.n
        );
        for gr in rect.r0..rect.r1 {
            for gc in rect.c0..rect.c1 {
                let row0 = gr * k;
                let col0 = gc * k;
                if row0 >= g.dim || col0 >= g.dim {
                    continue; // fully outside (possible for trailing cells)
                }
                scheduled += 1;
                let rows = (g.dim - row0).min(k);
                let cols = (g.dim - col0).min(k);
                let block = m.dense_block(row0, col0, k);
                let mut data = Vec::with_capacity(rows * cols);
                for r in 0..rows {
                    for c in 0..cols {
                        data.push(block[r * k + c] as f32);
                    }
                }
                if data.iter().all(|v| *v == 0.0) {
                    elided += 1;
                    continue;
                }
                // dedup key: extents + exact bit pattern
                let mut key = Vec::with_capacity(data.len() + 2);
                key.push(rows as u32);
                key.push(cols as u32);
                key.extend(data.iter().map(|v| v.to_bits()));
                let program = match dedup.get(&key) {
                    Some(&p) => p,
                    None => {
                        let p = programs.len();
                        programs.push(data);
                        dedup.insert(key, p);
                        p
                    }
                };
                tiles.push(TileSpec {
                    row0,
                    col0,
                    rows,
                    cols,
                    program,
                });
            }
        }
    }
    Ok(ExecPlan {
        k,
        dim: g.dim,
        tiles,
        programs,
        scheduled_tiles: scheduled,
        elided_tiles: elided,
    })
}

/// Merge several plans over the *same* matrix into one flat schedule — the
/// multi-plan path the mapper uses: each window of a composite mapping
/// compiles to its own [`ExecPlan`], and the merged plan is what a
/// [`super::fleet::Fleet`] distributes and a
/// [`super::batch::BatchExecutor`] serves. Tiles concatenate in part
/// order (so accumulation order is the parts' order), and bit-identical
/// programmings are re-deduplicated *across* parts — repeated window
/// sparsity patterns share one program buffer fleet-wide.
pub fn merge_plans(parts: &[ExecPlan]) -> Result<ExecPlan> {
    ensure!(!parts.is_empty(), "cannot merge zero plans");
    let k = parts[0].k;
    let dim = parts[0].dim;
    let mut tiles = Vec::new();
    let mut programs: Vec<Vec<f32>> = Vec::new();
    let mut dedup: HashMap<Vec<u32>, usize> = HashMap::new();
    let mut scheduled = 0usize;
    let mut elided = 0usize;
    for (i, p) in parts.iter().enumerate() {
        ensure!(
            p.k == k && p.dim == dim,
            "part {i} is {}x{} tiles over a {}-unit matrix; expected k={k}, dim={dim}",
            p.k,
            p.k,
            p.dim
        );
        scheduled += p.scheduled_tiles;
        elided += p.elided_tiles;
        // dedup each part-program once (keyed by extents + bit pattern,
        // taken from its first referencing tile — all tiles sharing a
        // program share extents, that is what the part's compile deduped
        // on), then remap tiles in O(1) each
        let mut remap: Vec<Option<usize>> = vec![None; p.programs.len()];
        for t in &p.tiles {
            let program = match remap[t.program] {
                Some(id) => id,
                None => {
                    let data = &p.programs[t.program];
                    let mut key = Vec::with_capacity(data.len() + 2);
                    key.push(t.rows as u32);
                    key.push(t.cols as u32);
                    key.extend(data.iter().map(|v| v.to_bits()));
                    let id = match dedup.get(&key) {
                        Some(&id) => id,
                        None => {
                            let id = programs.len();
                            programs.push(data.clone());
                            dedup.insert(key, id);
                            id
                        }
                    };
                    remap[t.program] = Some(id);
                    id
                }
            };
            tiles.push(TileSpec {
                row0: t.row0,
                col0: t.col0,
                rows: t.rows,
                cols: t.cols,
                program,
            });
        }
    }
    Ok(ExecPlan {
        k,
        dim,
        tiles,
        programs,
        scheduled_tiles: scheduled,
        elided_tiles: elided,
    })
}

impl ExecPlan {
    /// y' = A'x' over the scheduled tiles, writing into a reusable output
    /// buffer (cleared and resized to `dim`). Accumulation order matches
    /// [`crate::crossbar::CrossbarArray::mvm`].
    pub fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>) {
        assert_eq!(x.len(), self.dim, "input vector length mismatch");
        y.clear();
        y.resize(self.dim, 0.0);
        for t in &self.tiles {
            let prog = &self.programs[t.program];
            for r in 0..t.rows {
                let row = &prog[r * t.cols..r * t.cols + t.cols];
                let xs = &x[t.col0..t.col0 + t.cols];
                let mut acc = 0.0f64;
                for (gv, xv) in row.iter().zip(xs.iter()) {
                    acc += *gv as f64 * xv;
                }
                y[t.row0 + r] += acc;
            }
        }
    }

    /// Allocating convenience wrapper around [`Self::mvm_into`].
    pub fn mvm(&self, x: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.mvm_into(x, &mut y);
        y
    }

    /// Fraction of scheduled tiles dropped because they held no non-zeros.
    pub fn elision_ratio(&self) -> f64 {
        if self.scheduled_tiles == 0 {
            0.0
        } else {
            self.elided_tiles as f64 / self.scheduled_tiles as f64
        }
    }

    /// Fraction of placed tiles served by a shared (deduplicated) program.
    pub fn dedup_ratio(&self) -> f64 {
        if self.tiles.is_empty() {
            0.0
        } else {
            1.0 - self.programs.len() as f64 / self.tiles.len() as f64
        }
    }

    /// Programmed cells inside the matrix (Σ rows·cols over the schedule).
    pub fn cells(&self) -> u64 {
        self.tiles
            .iter()
            .map(|t| (t.rows * t.cols) as u64)
            .sum()
    }

    /// Non-zero count per program buffer (used by load-balancing policies).
    pub fn program_nnz(&self) -> Vec<u64> {
        self.programs
            .iter()
            .map(|p| p.iter().filter(|v| **v != 0.0).count() as u64)
            .collect()
    }

    // ---- serialization ---------------------------------------------------

    /// Serialize to the deployable JSON artifact format (version 1).
    pub fn to_json(&self) -> Json {
        let tiles = self
            .tiles
            .iter()
            .map(|t| {
                // flat [row0, col0, rows, cols, program] keeps the artifact
                // compact; the field order is part of the format.
                num_arr([
                    t.row0 as f64,
                    t.col0 as f64,
                    t.rows as f64,
                    t.cols as f64,
                    t.program as f64,
                ])
            })
            .collect();
        let programs = self
            .programs
            .iter()
            .map(|p| num_arr(p.iter().map(|&v| v as f64)))
            .collect();
        obj(vec![
            ("version", Json::Num(1.0)),
            ("k", Json::Num(self.k as f64)),
            ("dim", Json::Num(self.dim as f64)),
            ("scheduled_tiles", Json::Num(self.scheduled_tiles as f64)),
            ("elided_tiles", Json::Num(self.elided_tiles as f64)),
            ("tiles", Json::Arr(tiles)),
            ("programs", Json::Arr(programs)),
        ])
    }

    /// Parse and validate a plan document.
    pub fn from_json(doc: &Json) -> Result<ExecPlan> {
        let version = doc.get("version").as_usize().context("plan missing version")?;
        ensure!(version == 1, "unsupported plan version {version}");
        let k = doc.get("k").as_usize().context("plan missing k")?;
        let dim = doc.get("dim").as_usize().context("plan missing dim")?;
        ensure!(k >= 1 && dim >= 1, "plan has degenerate geometry");
        let scheduled_tiles = doc
            .get("scheduled_tiles")
            .as_usize()
            .context("plan missing scheduled_tiles")?;
        let elided_tiles = doc
            .get("elided_tiles")
            .as_usize()
            .context("plan missing elided_tiles")?;
        let mut programs = Vec::new();
        for (i, p) in doc
            .get("programs")
            .as_arr()
            .context("plan missing programs")?
            .iter()
            .enumerate()
        {
            let vals = p.as_arr().with_context(|| format!("program {i} not an array"))?;
            let mut data = Vec::with_capacity(vals.len());
            for v in vals {
                data.push(v.as_f64().with_context(|| format!("program {i}: non-number"))? as f32);
            }
            programs.push(data);
        }
        let mut tiles = Vec::new();
        for (i, t) in doc
            .get("tiles")
            .as_arr()
            .context("plan missing tiles")?
            .iter()
            .enumerate()
        {
            let f = t.as_arr().with_context(|| format!("tile {i} not an array"))?;
            ensure!(f.len() == 5, "tile {i} needs 5 fields, got {}", f.len());
            let mut nums = [0usize; 5];
            for (slot, v) in nums.iter_mut().zip(f.iter()) {
                *slot = v.as_usize().with_context(|| format!("tile {i}: bad field"))?;
            }
            let spec = TileSpec {
                row0: nums[0],
                col0: nums[1],
                rows: nums[2],
                cols: nums[3],
                program: nums[4],
            };
            if spec.rows == 0 || spec.cols == 0 || spec.rows > k || spec.cols > k {
                bail!("tile {i} has extents {}x{} outside 1..={k}", spec.rows, spec.cols);
            }
            if spec.row0 + spec.rows > dim || spec.col0 + spec.cols > dim {
                bail!("tile {i} exceeds the {dim}-unit matrix");
            }
            let prog = programs
                .get(spec.program)
                .with_context(|| format!("tile {i} references missing program {}", spec.program))?;
            if prog.len() != spec.rows * spec.cols {
                bail!(
                    "tile {i} is {}x{} but program {} has {} elements",
                    spec.rows,
                    spec.cols,
                    spec.program,
                    prog.len()
                );
            }
            tiles.push(spec);
        }
        ensure!(
            tiles.len() + elided_tiles == scheduled_tiles,
            "plan tile accounting is inconsistent: {} placed + {} elided != {} scheduled",
            tiles.len(),
            elided_tiles,
            scheduled_tiles
        );
        Ok(ExecPlan {
            k,
            dim,
            tiles,
            programs,
            scheduled_tiles,
            elided_tiles,
        })
    }

    /// Write the plan artifact to disk.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing plan {}", path.display()))
    }

    /// Load a plan artifact from disk.
    pub fn load(path: &Path) -> Result<ExecPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan {}", path.display()))?;
        let doc = Json::parse(&text)
            .with_context(|| format!("plan {} is not valid JSON", path.display()))?;
        Self::from_json(&doc).with_context(|| format!("parsing plan {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::place;
    use crate::graph::synth;
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::{parse_actions, FillRule};
    use crate::util::propcheck::check;

    fn qh882_setup() -> (Csr, GridSummary) {
        let m = synth::qh882_like(1);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 32);
        (r.matrix, g)
    }

    #[test]
    fn full_block_plan_elides_empty_tiles_and_matches_oracle() {
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        let arr = place(&m, &g, &scheme).unwrap();
        assert_eq!(plan.scheduled_tiles, arr.tiles.len());
        assert_eq!(plan.tiles.len() + plan.elided_tiles, plan.scheduled_tiles);
        // a CM-reordered banded matrix leaves most of the full block empty
        assert!(
            plan.elision_ratio() > 0.5,
            "elision {} too low",
            plan.elision_ratio()
        );
        let x: Vec<f64> = (0..g.dim).map(|i| ((i * 13) % 17) as f64 - 8.0).collect();
        let y = plan.mvm(&x);
        let want = arr.mvm(&x);
        for (a, b) in y.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn clipped_cells_match_scheme_area_on_full_block() {
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        // every *placed* tile's clipped extents stay inside the matrix
        for t in &plan.tiles {
            assert!(t.row0 + t.rows <= 882 && t.col0 + t.cols <= 882);
            assert_eq!(plan.programs[t.program].len(), t.rows * t.cols);
        }
        // scheduled (pre-elision) clipped area would equal 882²; placed
        // cells are a subset
        assert!(plan.cells() <= 882 * 882);
        assert!(plan.cells() > 0);
    }

    #[test]
    fn dedup_shares_identical_programs() {
        // batch supermatrix of identical sub-graphs: the diagonal blocks
        // repeat, so unit-tiling them must dedup heavily.
        let sub = synth::qm7_like(5828);
        let m = synth::batch_supermatrix(&[sub.clone(), sub.clone(), sub.clone()]);
        let g = GridSummary::new(&m, 22);
        let scheme = Scheme {
            diag_len: vec![1; g.n],
            fill_len: vec![0; g.n - 1],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        assert_eq!(plan.tiles.len(), 3);
        assert_eq!(plan.programs.len(), 1, "identical sub-graphs must share a program");
        assert!(plan.dedup_ratio() > 0.6);
        // and the shared program still computes correctly per tile position
        let x: Vec<f64> = (0..66).map(|i| (i as f64 * 0.31).cos()).collect();
        let y = plan.mvm(&x);
        let want = m.spmv(&x);
        for (a, b) in y.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn json_roundtrip_preserves_plan() {
        let (m, g) = qh882_setup();
        let scheme = parse_actions(
            g.n,
            &vec![1u8; g.n - 1],
            &vec![0usize; g.n - 1],
            FillRule::None,
        );
        let plan = compile(&m, &g, &scheme).unwrap();
        let doc = plan.to_json();
        let back = ExecPlan::from_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let sub = synth::qm7_like(5828);
        let g = GridSummary::new(&sub, 2);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&sub, &g, &scheme).unwrap();
        let dir = std::env::temp_dir().join("autogmap_engine_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        plan.save(&path).unwrap();
        let back = ExecPlan::load(&path).unwrap();
        assert_eq!(plan, back);
        let x: Vec<f64> = (0..22).map(|i| i as f64 - 11.0).collect();
        assert_eq!(plan.mvm(&x), back.mvm(&x));
    }

    #[test]
    fn from_json_rejects_corrupt_plans() {
        for text in [
            "{}",
            r#"{"version":2,"k":2,"dim":4,"scheduled_tiles":0,"elided_tiles":0,"tiles":[],"programs":[]}"#,
            // tile referencing a missing program
            r#"{"version":1,"k":2,"dim":4,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"programs":[]}"#,
            // tile exceeding the matrix
            r#"{"version":1,"k":2,"dim":3,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[2,2,2,2,0]],"programs":[[1,0,0,1]]}"#,
            // program length mismatch
            r#"{"version":1,"k":2,"dim":4,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"programs":[[1,0]]}"#,
            // inconsistent accounting
            r#"{"version":1,"k":2,"dim":4,"scheduled_tiles":5,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"programs":[[1,0,0,1]]}"#,
        ] {
            let doc = Json::parse(text).unwrap();
            assert!(ExecPlan::from_json(&doc).is_err(), "should reject {text}");
        }
    }

    #[test]
    fn compile_rects_matches_compile_on_schemes() {
        let (m, g) = qh882_setup();
        let scheme = parse_actions(
            g.n,
            &vec![0u8; g.n - 1],
            &vec![1usize; g.n - 1],
            FillRule::Fixed { size: 1 },
        );
        let a = compile(&m, &g, &scheme).unwrap();
        let b = compile_rects(&m, &g, &scheme.rects()).unwrap();
        assert_eq!(a, b);
        // out-of-grid rects are rejected
        let bad = [crate::scheme::GridRect { r0: 0, r1: g.n + 1, c0: 0, c1: 1 }];
        assert!(compile_rects(&m, &g, &bad).is_err());
    }

    #[test]
    fn merge_plans_concatenates_and_dedups() {
        let (m, g) = qh882_setup();
        // two disjoint halves of the unit-block diagonal, merged, must equal
        // the plan compiled from the whole diagonal at once
        let half = g.n / 2;
        let lo: Vec<crate::scheme::GridRect> =
            (0..half).map(|i| crate::scheme::GridRect::square(i, 1)).collect();
        let hi: Vec<crate::scheme::GridRect> =
            (half..g.n).map(|i| crate::scheme::GridRect::square(i, 1)).collect();
        let p_lo = compile_rects(&m, &g, &lo).unwrap();
        let p_hi = compile_rects(&m, &g, &hi).unwrap();
        let merged = merge_plans(&[p_lo.clone(), p_hi.clone()]).unwrap();
        let whole = compile_rects(
            &m,
            &g,
            &(0..g.n).map(|i| crate::scheme::GridRect::square(i, 1)).collect::<Vec<_>>(),
        )
        .unwrap();
        assert_eq!(merged.tiles.len(), whole.tiles.len());
        assert_eq!(merged.scheduled_tiles, whole.scheduled_tiles);
        assert_eq!(merged.elided_tiles, whole.elided_tiles);
        assert_eq!(merged.programs.len(), whole.programs.len(), "cross-part dedup");
        let x: Vec<f64> = (0..g.dim).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
        assert_eq!(merged.mvm(&x), whole.mvm(&x));
        // dimension mismatches are rejected
        let sub = synth::qm7_like(5828);
        let gs = GridSummary::new(&sub, 2);
        let tiny = compile_rects(&sub, &gs, &[crate::scheme::GridRect::square(0, 1)]).unwrap();
        assert!(merge_plans(&[p_lo, tiny]).is_err());
        assert!(merge_plans(&[]).is_err());
    }

    #[test]
    fn compile_rejects_invalid_scheme() {
        let (m, g) = qh882_setup();
        let bad = Scheme {
            diag_len: vec![g.n + 1],
            fill_len: vec![],
        };
        assert!(compile(&m, &g, &bad).is_err());
    }

    #[test]
    fn random_scheme_plans_match_oracle_property() {
        check("engine_plan_matches_oracle", 15, |rng| {
            let m = synth::molecule_like(30, 80, rng.next_u64());
            let r = reorder(&m, Reordering::CuthillMckee);
            let grid = 2 + rng.below(4) as usize;
            let g = GridSummary::new(&r.matrix, grid);
            if g.n < 2 {
                return Ok(());
            }
            let d: Vec<u8> = (0..g.n - 1).map(|_| rng.below(2) as u8).collect();
            let f: Vec<usize> = (0..g.n - 1).map(|_| rng.below(4) as usize).collect();
            let s = parse_actions(g.n, &d, &f, FillRule::Dynamic { grades: 4 });
            let plan = compile(&r.matrix, &g, &s).map_err(|e| format!("{e:#}"))?;
            let arr = place(&r.matrix, &g, &s).map_err(|e| format!("{e:#}"))?;
            let x: Vec<f64> = (0..g.dim).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let y = plan.mvm(&x);
            let want = arr.mvm(&x);
            for (i, (a, b)) in y.iter().zip(want.iter()).enumerate() {
                if (a - b).abs() > 1e-9 {
                    return Err(format!("row {i}: plan {a} vs oracle {b}"));
                }
            }
            Ok(())
        });
    }
}
