//! Native pure-Rust training backend: batched sampling rollouts across a
//! std-thread [`WorkerPool`], full backprop-through-time
//! ([`bptt::episode_gradient`]), the REINFORCE-with-baseline gradient, and
//! a fused Adam update — no PJRT artifacts required.
//!
//! Determinism: results are bit-identical for a fixed seed **regardless of
//! worker count**. Per-episode [`Pcg64`] streams are derived sequentially
//! from the epoch key before any job is dispatched, and both action
//! concatenation and gradient reduction happen in episode order on the
//! caller thread, so thread scheduling never reorders a floating-point
//! sum.

pub mod bptt;

use crate::agent::backend::{RolloutBatch, StepStats, TrainBackend};
use crate::agent::lstm::{forward, Select};
use crate::agent::params::{init_params, AdamState, Params};
use crate::runtime::manifest::ControllerEntry;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// Offsets of each parameter tensor inside one flat ABI-order f32 buffer
/// (the gradient/Adam layout).
pub struct ParamLayout {
    /// (name, offset, len) in ABI (manifest) order
    spans: Vec<(String, usize, usize)>,
    pub total: usize,
}

impl ParamLayout {
    pub fn new(entry: &ControllerEntry) -> ParamLayout {
        let mut spans = Vec::with_capacity(entry.params.len());
        let mut off = 0;
        for spec in &entry.params {
            spans.push((spec.name.clone(), off, spec.elements()));
            off += spec.elements();
        }
        ParamLayout { spans, total: off }
    }

    pub fn zeros(&self) -> Vec<f32> {
        vec![0.0; self.total]
    }

    /// Flat range of one named tensor.
    pub fn range(&self, name: &str) -> std::ops::Range<usize> {
        let s = self
            .spans
            .iter()
            .find(|(n, _, _)| n.as_str() == name)
            .unwrap_or_else(|| panic!("no param {name} in layout"));
        s.1..s.1 + s.2
    }

    /// Map a flat index back to (tensor name, index within tensor).
    pub fn locate(&self, flat: usize) -> (&str, usize) {
        for (name, off, len) in &self.spans {
            if flat >= *off && flat < off + len {
                return (name.as_str(), flat - off);
            }
        }
        panic!("flat index {flat} out of range ({} total)", self.total)
    }
}

/// Stream constant separating native rollout entropy from every other
/// consumer of the run seed.
const ROLLOUT_STREAM: u64 = 0x6e61_7469_7665_0001; // "native"

/// Batch inference entry point on the native backend: sample
/// `rounds × entry.batch` episodes for the given PRNG key plus one greedy
/// decode, with no Trainer, optimizer, or worker pool attached — the
/// caller (the [`crate::mapper`] pipeline) parallelizes across *windows*
/// instead of across episodes, so this stays a pure function of
/// `(entry, params, key, rounds)` and is safe to run concurrently from
/// many threads. Episode RNG streams are derived exactly like
/// [`NativeBackend::sample_batch`]'s, so results are reproducible and
/// independent of the calling thread.
pub fn infer_episodes(
    entry: &ControllerEntry,
    params: &crate::agent::params::Params,
    key: [u32; 2],
    rounds: usize,
) -> Vec<crate::agent::lstm::Episode> {
    let mut root = Pcg64::new(((key[0] as u64) << 32) | key[1] as u64, ROLLOUT_STREAM);
    let mut episodes = Vec::with_capacity(rounds * entry.batch + 1);
    for _ in 0..rounds * entry.batch {
        let (seed, stream) = (root.next_u64(), root.next_u64());
        let mut rng = Pcg64::new(seed, stream);
        episodes.push(forward(entry, params, Select::Sample(&mut rng)));
    }
    episodes.push(forward(entry, params, Select::Greedy));
    episodes
}

/// The pure-Rust [`TrainBackend`].
pub struct NativeBackend {
    entry: Arc<ControllerEntry>,
    layout: Arc<ParamLayout>,
    params: Params,
    opt: AdamState,
    pool: WorkerPool,
}

impl NativeBackend {
    /// Fresh backend: parameters drawn from the same Uniform(-0.1, 0.1)
    /// init as the AOT path, with `workers` rollout/BPTT threads.
    pub fn new(entry: ControllerEntry, seed: u64, workers: usize) -> NativeBackend {
        let params = init_params(&entry, seed);
        let opt = AdamState::new(&entry);
        let layout = Arc::new(ParamLayout::new(&entry));
        NativeBackend {
            pool: WorkerPool::new(workers.max(1)),
            layout,
            entry: Arc::new(entry),
            params,
            opt,
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Sample one batch of episodes (also the `train-bench` rollout probe).
    pub fn sample_batch(&self, key: [u32; 2]) -> RolloutBatch {
        let (b, t) = (self.entry.batch, self.entry.steps);
        // derive every episode's (seed, stream) pair sequentially *before*
        // dispatch — worker count cannot change what any episode samples
        let mut root = Pcg64::new(((key[0] as u64) << 32) | key[1] as u64, ROLLOUT_STREAM);
        let seeds: Vec<(u64, u64)> = (0..b).map(|_| (root.next_u64(), root.next_u64())).collect();
        let params = Arc::new(self.params.clone());
        let jobs: Vec<_> = seeds
            .into_iter()
            .map(|(seed, stream)| {
                let params = params.clone();
                let entry = self.entry.clone();
                move || {
                    let mut rng = Pcg64::new(seed, stream);
                    forward(&entry, &params, Select::Sample(&mut rng))
                }
            })
            .collect();
        let episodes = self.pool.run(jobs);
        let mut d_all = Vec::with_capacity(b * t);
        let mut f_all = Vec::with_capacity(b * t);
        for ep in &episodes {
            d_all.extend_from_slice(&ep.d_actions);
            f_all.extend_from_slice(&ep.f_actions);
        }
        RolloutBatch { d_all, f_all }
    }
}

impl TrainBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn rollout(&mut self, key: [u32; 2]) -> Result<RolloutBatch> {
        Ok(self.sample_batch(key))
    }

    fn train_step(
        &mut self,
        d_all: &[i32],
        f_all: &[i32],
        adv: &[f32],
        lr: f32,
        ent_coef: f32,
    ) -> Result<StepStats> {
        let (b, t) = (self.entry.batch, self.entry.steps);
        ensure!(
            d_all.len() == b * t && f_all.len() == b * t,
            "train_step wants [B={b}, T={t}] actions, got {} / {}",
            d_all.len(),
            f_all.len()
        );
        ensure!(adv.len() == b, "need {b} advantages, got {}", adv.len());

        // fan per-episode BPTT out; each job's gradient is pre-scaled so
        // the in-order sum below is exactly d/dθ of
        // -mean(adv · logp) - ent_coef · mean(H)
        let params = Arc::new(self.params.clone());
        let inv_b = 1.0f32 / b as f32;
        let jobs: Vec<_> = (0..b)
            .map(|i| {
                let params = params.clone();
                let entry = self.entry.clone();
                let layout = self.layout.clone();
                let d: Vec<i32> = d_all[i * t..(i + 1) * t].to_vec();
                let f: Vec<i32> = f_all[i * t..(i + 1) * t].to_vec();
                let coef_logp = -adv[i] * inv_b;
                let coef_ent = -ent_coef * inv_b;
                move || bptt::episode_gradient(&entry, &params, &layout, &d, &f, coef_logp, coef_ent)
            })
            .collect();
        let grads = self.pool.run(jobs);

        // deterministic reduction in episode order
        let mut total = self.layout.zeros();
        let mut loss = 0.0f32;
        let mut sum_logp = 0.0f32;
        for (i, g) in grads.iter().enumerate() {
            for (acc, &x) in total.iter_mut().zip(g.grad.iter()) {
                *acc += x;
            }
            loss += (-adv[i] * g.logp - ent_coef * g.entropy) * inv_b;
            sum_logp += g.logp;
        }
        self.opt.apply_flat(&self.entry, &mut self.params, &total, lr)?;
        Ok(StepStats {
            loss,
            mean_logp: sum_logp * inv_b,
        })
    }

    fn greedy(&mut self) -> Result<(Vec<i32>, Vec<i32>)> {
        let ep = forward(&self.entry, &self.params, Select::Greedy);
        Ok((ep.d_actions, ep.f_actions))
    }

    fn params(&self) -> Result<Params> {
        Ok(self.params.clone())
    }

    fn opt_state(&self) -> Result<AdamState> {
        Ok(self.opt.clone())
    }

    fn load_state(&mut self, params: Params, opt: AdamState) -> Result<()> {
        for spec in &self.entry.params {
            match params.get(&spec.name) {
                Some(v) if v.len() == spec.elements() => {}
                Some(v) => bail!(
                    "param {} has {} elements, ABI wants {:?}",
                    spec.name,
                    v.len(),
                    spec.shape
                ),
                None => bail!("restore is missing param {}", spec.name),
            }
        }
        self.params = params;
        self.opt = opt;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::lstm::forward as mirror_forward;
    use crate::runtime::Manifest;

    fn small_entry(fill: usize, bilstm: bool) -> ControllerEntry {
        ControllerEntry::from_dims("native_test", 6, 5, fill, 4, bilstm)
    }

    #[test]
    fn layout_roundtrips_names_and_indices() {
        let e = small_entry(4, true);
        let layout = ParamLayout::new(&e);
        assert_eq!(layout.total, e.total_param_elements());
        let r = layout.range("lstm_w");
        assert_eq!(r.len(), 2 * 5 * 4 * 5);
        let (name, idx) = layout.locate(r.start + 7);
        assert_eq!((name, idx), ("lstm_w", 7));
        let (name, _) = layout.locate(layout.total - 1);
        assert_eq!(name, "fc_f_b");
    }

    #[test]
    fn rollouts_are_identical_across_worker_counts() {
        for (fill, bilstm) in [(0, false), (4, false), (2, true)] {
            let a = NativeBackend::new(small_entry(fill, bilstm), 9, 1);
            let b = NativeBackend::new(small_entry(fill, bilstm), 9, 4);
            for key in [[1u32, 2u32], [3, 4], [0xffff_ffff, 0]] {
                let ra = a.sample_batch(key);
                let rb = b.sample_batch(key);
                assert_eq!(ra.d_all, rb.d_all);
                assert_eq!(ra.f_all, rb.f_all);
            }
        }
    }

    #[test]
    fn infer_episodes_is_deterministic_and_matches_sample_batch() {
        let entry = small_entry(4, false);
        let params = crate::agent::params::init_params(&entry, 11);
        let a = infer_episodes(&entry, &params, [3, 4], 2);
        let b = infer_episodes(&entry, &params, [3, 4], 2);
        assert_eq!(a.len(), 2 * entry.batch + 1);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.d_actions, y.d_actions);
            assert_eq!(x.f_actions, y.f_actions);
        }
        // the first `batch` episodes reproduce sample_batch's first batch
        // for the same key (same stream derivation)
        let be = NativeBackend::new(entry.clone(), 11, 2);
        let rb = be.sample_batch([3, 4]);
        let t = entry.steps;
        for (i, ep) in a.iter().take(entry.batch).enumerate() {
            assert_eq!(&ep.d_actions[..], &rb.d_all[i * t..(i + 1) * t]);
        }
        // last episode is the greedy decode
        let greedy = mirror_forward(&entry, &params, Select::Greedy);
        assert_eq!(a.last().unwrap().d_actions, greedy.d_actions);
        // different keys sample differently
        let c = infer_episodes(&entry, &params, [3, 5], 2);
        assert_ne!(
            a.iter().map(|e| e.d_actions.clone()).collect::<Vec<_>>(),
            c.iter().map(|e| e.d_actions.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn rollout_actions_are_valid_and_key_dependent() {
        let be = NativeBackend::new(small_entry(4, false), 1, 2);
        let r = be.sample_batch([5, 6]);
        let e = small_entry(4, false);
        assert_eq!(r.d_all.len(), e.batch * e.steps);
        assert!(r.d_all.iter().all(|&d| d == 0 || d == 1));
        assert!(r.f_all.iter().all(|&f| f >= 0 && (f as usize) < 4));
        let r2 = be.sample_batch([5, 7]);
        assert_ne!(
            (&r.d_all, &r.f_all),
            (&r2.d_all, &r2.f_all),
            "different keys must sample different batches"
        );
    }

    #[test]
    fn train_step_is_deterministic_across_worker_counts() {
        let mk = |workers| NativeBackend::new(small_entry(4, false), 42, workers);
        let mut a = mk(1);
        let mut b = mk(8);
        for round in 0..5u32 {
            let batch = a.sample_batch([round, 99]);
            let adv = [0.5f32, -0.25, 1.0, -1.0];
            let sa = a
                .train_step(&batch.d_all, &batch.f_all, &adv, 0.05, 0.01)
                .unwrap();
            let sb = b
                .train_step(&batch.d_all, &batch.f_all, &adv, 0.05, 0.01)
                .unwrap();
            assert_eq!(sa.loss.to_bits(), sb.loss.to_bits(), "round {round}");
            assert_eq!(sa.mean_logp.to_bits(), sb.mean_logp.to_bits());
        }
        assert_eq!(a.params().unwrap(), b.params().unwrap());
        let oa = a.opt_state().unwrap();
        let ob = b.opt_state().unwrap();
        assert_eq!(oa.t, ob.t);
        assert_eq!(oa.m, ob.m);
        assert_eq!(oa.v, ob.v);
    }

    #[test]
    fn positive_advantage_raises_action_logp() {
        // the native analogue of the PJRT train-artifact test: repeating a
        // step with adv = +1 on fixed actions must raise their log-prob
        let entry = small_entry(4, false);
        let (b, t) = (entry.batch, entry.steps);
        let mut be = NativeBackend::new(entry.clone(), 13, 2);
        let d = vec![0i32; b * t];
        let f = vec![0i32; b * t];
        let adv = vec![1.0f32; b];
        let before = mirror_forward(
            &entry,
            &be.params().unwrap(),
            Select::Teacher { d: &d[..t], f: &f[..t] },
        )
        .logp;
        for _ in 0..5 {
            be.train_step(&d, &f, &adv, 0.05, 0.0).unwrap();
        }
        let after = mirror_forward(
            &entry,
            &be.params().unwrap(),
            Select::Teacher { d: &d[..t], f: &f[..t] },
        )
        .logp;
        assert!(after > before, "logp {before} -> {after}");
        assert_eq!(be.opt_state().unwrap().t, 5);
    }

    #[test]
    fn greedy_is_deterministic_and_valid() {
        let mut be = NativeBackend::new(small_entry(2, true), 31, 2);
        let (d1, f1) = be.greedy().unwrap();
        let (d2, f2) = be.greedy().unwrap();
        assert_eq!(d1, d2);
        assert_eq!(f1, f2);
        assert_eq!(d1.len(), small_entry(2, true).steps);
    }

    #[test]
    fn load_state_validates_shapes() {
        let entry = small_entry(0, false);
        let mut be = NativeBackend::new(entry.clone(), 1, 1);
        let good = be.params().unwrap();
        let opt = be.opt_state().unwrap();
        assert!(be.load_state(good.clone(), opt.clone()).is_ok());
        let mut bad = good.clone();
        bad.get_mut("x0").unwrap().push(0.0);
        assert!(be.load_state(bad, opt.clone()).is_err());
        let mut missing = good;
        missing.remove("lstm_b");
        assert!(be.load_state(missing, opt).is_err());
    }

    #[test]
    fn builtin_configs_all_train_one_step() {
        // every paper config must run a rollout + gradient step natively
        let m = Manifest::builtin();
        for entry in m.configs.values() {
            let mut be = NativeBackend::new(entry.clone(), 7, 2);
            let batch = be.sample_batch([1, 2]);
            let adv = vec![0.1f32; entry.batch];
            let stats = be
                .train_step(&batch.d_all, &batch.f_all, &adv, 0.01, 0.001)
                .unwrap();
            assert!(stats.loss.is_finite(), "{}: loss not finite", entry.name);
            assert!(stats.mean_logp < 0.0, "{}: mean_logp", entry.name);
        }
    }
}
