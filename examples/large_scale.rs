//! Large-scale run: reproduce one bold row of Table IV end-to-end.
//!
//! Trains LSTM+RL+Dynamic-fill (grades 6, a=0.8) on the qh882-like matrix
//! at grid 32, prints the training curves, compares the converged scheme
//! against every baseline, and reports the crossbar deployment cost of the
//! winning scheme.
//!
//! Run: `make artifacts && cargo run --release --example large_scale`
//! (about a minute; use AUTOGMAP_EPOCHS to override the epoch budget)

use autogmap::baselines;
use autogmap::coordinator::config::{Dataset, ExperimentConfig};
use autogmap::coordinator::{run_experiment, runner, RunnerOptions};
use autogmap::crossbar::cost::CostModel;
use autogmap::crossbar::place;
use autogmap::crossbar::switch::SwitchCircuit;
use autogmap::reorder::Reordering;
use autogmap::runtime::Runtime;
use autogmap::scheme::{evaluate, eval::evaluate_rects, FillRule, RewardWeights};

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::var("AUTOGMAP_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    let cfg = ExperimentConfig {
        name: "table4_qh882_dyn6_a80".into(),
        dataset: Dataset::Qh882 { seed: 882 },
        grid: 32,
        reordering: Reordering::CuthillMckee,
        controller: "qh882_dyn6".into(),
        fill_rule: FillRule::Dynamic { grades: 6 },
        reward_a: 0.8,
        lr: 0.015,
        ent_coef: 0.002,
        baseline_decay: 0.95,
        epochs,
        seed: 3,
        log_every: 25,
    };
    let rt = Runtime::new("artifacts")?;
    println!(
        "training {} for {} epochs on qh882-like (882×882, sparsity ≈0.995) …",
        cfg.controller, epochs
    );
    let result = run_experiment(&rt, &cfg, &RunnerOptions::default())?;
    println!("{}", runner::curves_ascii(&result.history, 78, 16));

    let grid = &result.workload.grid;
    let best = result.best.as_ref().expect("no complete-coverage scheme found");
    println!(
        "best scheme (epoch {}): {} diagonal blocks {:?}",
        best.epoch,
        best.scheme.diag_len.len(),
        best.scheme.diag_sizes_units(grid)
    );
    println!(
        "fills {:?}  ->  C={:.3}  A={:.3}  sparsity={:.3}",
        best.scheme.fill_len,
        best.eval.coverage_ratio,
        best.eval.area_ratio,
        best.eval.sparsity
    );
    println!("paper Table IV (qh882, grades 6, a=0.8): C=1.0  A=0.225  sparsity=0.955");
    println!(
        "wall {:.1}s  ({:.0} epochs/s; paper: 40k epochs in minutes on an Intel CPU)",
        result.wall_seconds,
        epochs as f64 / result.wall_seconds
    );

    // --- baselines on the identical grid
    let w = RewardWeights::new(cfg.reward_a);
    println!("\nbaselines at grid 32:");
    for block in [1usize, 2, 4] {
        let s = baselines::vanilla(grid.n, block);
        let e = evaluate(&s, grid, w);
        println!(
            "  vanilla {:>3}-unit blocks: C {:.3}  A {:.3}",
            block * 32,
            e.coverage_ratio,
            e.area_ratio
        );
    }
    let sar = baselines::graphsar(grid, 8);
    let e = evaluate_rects(&sar, grid, w);
    println!(
        "  GraphSAR-like (whole-matrix, {} blocks): C {:.3}  A {:.3}",
        sar.len(),
        e.coverage_ratio,
        e.area_ratio
    );

    // --- deploy the winner on crossbars and price it
    let arr = place(&result.workload.reordered.matrix, grid, &best.scheme)?;
    let sw = SwitchCircuit::new(result.workload.reordered.perm.clone());
    let cost = CostModel::default().estimate(&arr, sw.crossover_count());
    println!(
        "\ndeployment: {} tiles of 32×32  ({} cells = {:.1}% of a monolithic 882² crossbar)",
        cost.tiles,
        cost.cells,
        100.0 * cost.cells as f64 / (882.0 * 882.0)
    );
    println!(
        "  energy {:.2} nJ/MVM   latency {:.1} µs/MVM   {} ADC row segments",
        cost.energy_pj / 1e3,
        cost.latency_ns / 1e3,
        cost.row_segments
    );
    // correctness of the deployed array
    let x: Vec<f64> = (0..882).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
    let y = sw.inverse(&arr.mvm(&sw.forward(&x)));
    let want = result.workload.original.spmv(&x);
    let diff = y
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    anyhow::ensure!(diff < 1e-9, "deployed MVM mismatch: {diff}");
    println!("  deployed y=Ax verified exact (max|Δ| = {diff:.1e})");
    Ok(())
}
