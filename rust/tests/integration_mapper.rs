//! Integration tests for the hierarchical mapper pipeline: the composite
//! principles under random windows/overlaps (propcheck), MatrixMarket
//! round-trips at 10k+ rows, and the acceptance property — composite batch
//! execution bit-identical to the dense oracle on a 10k-node R-MAT graph,
//! with a global area ratio strictly better than the fixed-block baseline
//! at the same window size.

use autogmap::agent::params::init_params;
use autogmap::baselines;
use autogmap::engine::BatchExecutor;
use autogmap::graph::{matrix_market, synth, Coo, Csr, GridSummary};
use autogmap::mapper::{self, MapperConfig};
use autogmap::reorder::{reorder, Reordering};
use autogmap::runtime::manifest::ControllerEntry;
use autogmap::scheme::{evaluate, FillRule, RewardWeights};
use autogmap::util::propcheck::check;
use std::sync::Arc;

fn mapper_cfg(n: usize, overlap: usize, rounds: usize, seed: u64, workers: usize) -> MapperConfig {
    let entry = ControllerEntry::from_dims("it_mapper", n, 5, 4, 4, false);
    let params = init_params(&entry, seed ^ 0xabcd);
    MapperConfig {
        infer: mapper::InferContext {
            entry,
            params,
            fill_rule: FillRule::Dynamic { grades: 4 },
            weights: RewardWeights::new(0.8),
            rounds,
            seed,
        },
        overlap,
        workers,
    }
}

fn random_sym(rng: &mut autogmap::util::rng::Pcg64, dim: usize, edges: usize) -> Csr {
    let mut coo = Coo::new(dim, dim);
    for _ in 0..edges {
        let a = rng.below(dim as u64) as usize;
        let b = rng.below(dim as u64) as usize;
        coo.push_sym(a.max(b), a.min(b), 1.0);
    }
    coo.to_csr()
}

/// The four scheme principles, checked globally on mapper-built composites
/// across random matrices, window sizes, and overlaps:
///   1. complete coverage of windowed nnz (every nnz in an owned square is
///      inside a mapped rect),
///   2. no overlap (rasterized),
///   3. conservation (covered + spilled = total, no double counting),
///   4. least-area monotonicity (the composite never costs more than one
///      fixed block per owned range — the windowing upper bound — and its
///      reported area equals the rasterized union).
#[test]
fn composite_preserves_scheme_principles_property() {
    check("mapper_composite_principles", 20, |rng| {
        let dim = 40 + rng.below(120) as usize;
        let grid = 2 + rng.below(4) as usize;
        let m = random_sym(rng, dim, dim * 2);
        let r = reorder(&m, Reordering::ReverseCuthillMckee);
        let g = GridSummary::new(&r.matrix, grid);
        let n_window = 4 + rng.below(5) as usize;
        let overlap = rng.below(n_window as u64 - 1) as usize;
        let cfg = mapper_cfg(n_window, overlap, 1 + rng.below(2) as usize, rng.next_u64(), 2);
        let (comp, report) = mapper::map_graph(&g, &cfg).map_err(|e| format!("{e:#}"))?;
        comp.validate(g.n).map_err(|e| format!("validate: {e}"))?;
        if report.windows != comp.slices.len() {
            return Err("report/slice count mismatch".into());
        }
        let eval = comp.evaluate(&g, 4);

        // rasterize the mapped rects over the grid
        let n = g.n;
        let mut covered = vec![false; n * n];
        for rect in comp.rects() {
            for rr in rect.r0..rect.r1 {
                for cc in rect.c0..rect.c1 {
                    if covered[rr * n + cc] {
                        return Err(format!("overlap at cell ({rr},{cc})"));
                    }
                    covered[rr * n + cc] = true;
                }
            }
        }
        // rects stay inside their slice's owned square
        for s in &comp.slices {
            for rect in s.rects() {
                if rect.r0 < s.start || rect.r1 > s.end || rect.c0 < s.start || rect.c1 > s.end {
                    return Err(format!("rect {rect:?} escapes owned [{}, {})", s.start, s.end));
                }
            }
        }
        // brute-force nnz accounting against the rasterization
        let (mut covered_nnz, mut windowed_nnz) = (0u64, 0u64);
        let owner = |cell: usize| -> usize {
            comp.slices
                .iter()
                .position(|s| cell >= s.start && cell < s.end)
                .expect("ownership partitions the grid")
        };
        for row in 0..g.dim {
            let rc = row / grid;
            for &col in r.matrix.row(row) {
                let cc = col / grid;
                if covered[rc * n + cc] {
                    covered_nnz += 1;
                }
                let in_window = owner(rc) == owner(cc);
                if in_window {
                    windowed_nnz += 1;
                    // principle 1: windowed nnz must be covered
                    if !covered[rc * n + cc] {
                        return Err(format!(
                            "windowed nnz at ({row},{col}) cell ({rc},{cc}) uncovered"
                        ));
                    }
                }
            }
        }
        if covered_nnz != eval.covered_nnz {
            return Err(format!("covered {covered_nnz} != eval {}", eval.covered_nnz));
        }
        if windowed_nnz != eval.windowed_nnz {
            return Err(format!("windowed {windowed_nnz} != eval {}", eval.windowed_nnz));
        }
        if eval.covered_nnz + eval.spilled_nnz != eval.total_nnz {
            return Err("conservation violated".into());
        }
        if (eval.coverage_windowed - 1.0).abs() > 1e-12 {
            return Err(format!("windowed coverage {}", eval.coverage_windowed));
        }
        // principle 4: area equals the rasterized union and never exceeds
        // the one-block-per-owned-range bound
        let union_area: u64 = (0..n * n)
            .filter(|&i| covered[i])
            .map(|i| {
                let (rr, cc) = (i / n, i % n);
                g.rect_area(rr, rr + 1, cc, cc + 1)
            })
            .sum();
        if union_area != eval.covered_area_units {
            return Err(format!(
                "union area {union_area} != eval {}",
                eval.covered_area_units
            ));
        }
        let bound: u64 = comp
            .slices
            .iter()
            .map(|s| g.rect_area(s.start, s.end, s.start, s.end))
            .sum();
        if eval.covered_area_units > bound {
            return Err(format!("area {} above fixed bound {bound}", eval.covered_area_units));
        }
        Ok(())
    });
}

/// In-window nnz (same owner for row and column cell) must be covered —
/// and an nnz whose cells have different owners must be exactly the spill.
#[test]
fn composite_spill_is_exactly_the_uncovered_remainder() {
    let m = synth::banded_like(500, 0.97, 11);
    let r = reorder(&m, Reordering::ReverseCuthillMckee);
    let g = GridSummary::new(&r.matrix, 8);
    let cfg = mapper_cfg(8, 3, 2, 21, 2);
    let (comp, _) = mapper::map_graph(&g, &cfg).unwrap();
    let cplan = mapper::compile_composite(&r.matrix, &g, &comp).unwrap();
    let eval = comp.evaluate(&g, 4);
    assert_eq!(cplan.spilled_nnz(), eval.spilled_nnz);
    assert_eq!(cplan.mapped_nnz(), eval.covered_nnz);
    assert_eq!(
        cplan.mapped_nnz() + cplan.spilled_nnz(),
        r.matrix.nnz() as u64
    );
}

/// Acceptance: composite batch execution on a 10k-node R-MAT graph is
/// bit-identical to the dense oracle (integer inputs make every
/// accumulation exact, so order cannot hide differences), for 1/2/8
/// workers, and the global area ratio strictly beats the fixed-block
/// baseline at the same window size.
#[test]
fn composite_execution_matches_dense_oracle_on_10k_rmat() {
    let nodes = 10_000;
    let m = synth::rmat_like(nodes, 60_000, 77);
    let r = reorder(&m, Reordering::ReverseCuthillMckee);
    let g = GridSummary::new(&r.matrix, 32);
    // the paper's qh882 controller shape: N=28 windows at grid 32
    let entry = autogmap::runtime::Manifest::builtin()
        .config("qh882_dyn4")
        .unwrap()
        .clone();
    let params = init_params(&entry, 5);
    let cfg = MapperConfig {
        infer: mapper::InferContext {
            entry: entry.clone(),
            params,
            fill_rule: FillRule::Dynamic { grades: 4 },
            weights: RewardWeights::new(0.8),
            rounds: 2,
            seed: 9,
        },
        overlap: 4,
        workers: 2,
    };
    let (comp, report) = mapper::map_graph(&g, &cfg).unwrap();
    assert!(report.windows > 2, "10k nodes must need several windows");
    let eval = comp.evaluate(&g, 4);
    assert_eq!(eval.coverage_windowed, 1.0);

    // area strictly better than the fixed-block baseline at window size
    let baseline = baselines::vanilla(g.n, entry.n);
    let be = evaluate(&baseline, &g, RewardWeights::new(0.8));
    assert!(
        eval.area_ratio < be.area_ratio,
        "composite area {} must beat fixed-block {}",
        eval.area_ratio,
        be.area_ratio
    );

    // bit-identical serving: integer-valued inputs -> exact arithmetic
    let cplan = Arc::new(mapper::compile_composite(&r.matrix, &g, &comp).unwrap());
    let xs: Vec<Vec<f64>> = (0..6)
        .map(|s| {
            (0..nodes)
                .map(|i| ((i * 13 + s * 7) % 21) as f64 - 10.0)
                .collect()
        })
        .collect();
    let want: Vec<Vec<f64>> = xs.iter().map(|x| r.matrix.spmv(x)).collect();
    assert_eq!(
        cplan.mvm(&xs[0]),
        want[0],
        "single composite MVM must equal the dense oracle bit-for-bit"
    );
    for workers in [1usize, 2, 8] {
        let exec = BatchExecutor::new(cplan.clone(), workers);
        let ys = exec.execute_batch(xs.clone());
        assert_eq!(ys, want, "batch execution at {workers} workers");
        let sharded = exec.execute_batch_sharded(xs.clone());
        assert_eq!(sharded, want, "band-sharded execution at {workers} workers");
    }
}

/// MatrixMarket round-trip at 10k+ rows: R-MAT graphs written and re-read
/// are identical (pattern, values, and dimensions).
#[test]
fn matrix_market_roundtrip_at_10k_rows_property() {
    let dir = std::env::temp_dir().join("autogmap_mapper_mtx_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    check("mapper_mtx_roundtrip_10k", 3, |rng| {
        let dim = 10_000 + rng.below(2_000) as usize;
        let nnz = 2 * (dim + rng.below(2 * dim as u64) as usize);
        let m = synth::rmat_like(dim, nnz, rng.next_u64());
        let path = dir.join(format!("rt_{dim}.mtx"));
        matrix_market::write(&path, &m).map_err(|e| e.to_string())?;
        let back = matrix_market::read(&path).map_err(|e| e.to_string())?;
        if back != m {
            return Err(format!("round-trip mismatch at dim {dim}"));
        }
        Ok(())
    });
}
