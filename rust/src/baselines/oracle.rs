//! DP oracle: optimal diagonal-only complete-coverage partition.
//!
//! A diagonal-only scheme achieves complete coverage iff every non-zero
//! (r,c) has both r and c inside the same block. A block over grid cells
//! [j,i) is *feasible* iff rows [j,i) contain no non-zeros outside columns
//! [j,i) (symmetry makes the column check redundant but we check both for
//! robustness to asymmetric inputs). The minimum-total-area partition is
//!
//!   dp[i] = min over feasible j<i of dp[j] + span(j, i-j)²
//!
//! computed in O(N²) with O(1) feasibility checks via grid prefix sums.
//! This is the tightest possible "LSTM+RL" (no-fill) result — used as the
//! ablation lower bound, and as a sanity check that REINFORCE converges
//! toward the optimum on small inputs.

use crate::graph::GridSummary;
use crate::scheme::Scheme;

/// Optimal complete-coverage diagonal partition, or `None` when even the
/// single full-matrix block is infeasible (cannot happen for square grids —
/// the full block always covers everything — so this is always `Some`).
pub fn optimal_diagonal(g: &GridSummary) -> Option<Scheme> {
    let n = g.n;
    if n == 0 {
        return None;
    }
    const INF: u64 = u64::MAX;
    let mut dp = vec![INF; n + 1];
    let mut prev = vec![usize::MAX; n + 1];
    dp[0] = 0;
    for i in 1..=n {
        for j in 0..i {
            if dp[j] == INF {
                continue;
            }
            if !block_feasible(g, j, i) {
                continue;
            }
            let cost = dp[j] + g.block_area(j, i - j);
            if cost < dp[i] {
                dp[i] = cost;
                prev[i] = j;
            }
        }
    }
    if dp[n] == INF {
        return None;
    }
    let mut cuts = Vec::new();
    let mut i = n;
    while i > 0 {
        let j = prev[i];
        cuts.push(i - j);
        i = j;
    }
    cuts.reverse();
    let fills = cuts.len() - 1;
    Some(Scheme {
        diag_len: cuts,
        fill_len: vec![0; fills],
    })
}

/// Is a diagonal block over grid cells [j,i) compatible with complete
/// coverage? (No nnz in its rows outside its columns, and vice versa.)
fn block_feasible(g: &GridSummary, j: usize, i: usize) -> bool {
    let n = g.n;
    g.nnz_rect(j, i, 0, j) == 0
        && g.nnz_rect(j, i, i, n) == 0
        && g.nnz_rect(0, j, j, i) == 0
        && g.nnz_rect(i, n, j, i) == 0
}

/// Total matrix-unit area of a diagonal partition.
pub fn partition_area(g: &GridSummary, diag_len: &[usize]) -> u64 {
    let mut area = 0;
    let mut g0 = 0;
    for &l in diag_len {
        area += g.block_area(g0, l);
        g0 += l;
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;
    use crate::graph::synth;
    use crate::graph::GridSummary;
    use crate::scheme::{evaluate, RewardWeights};
    use crate::util::propcheck::check;

    #[test]
    fn block_diagonal_matrix_recovers_blocks() {
        // two 3-cliques and a 2-clique on the diagonal: optimum is [3,3,2].
        let mut coo = Coo::new(8, 8);
        for base in [0, 3] {
            for a in 0..3 {
                for b in 0..3 {
                    coo.push(base + a, base + b, 1.0);
                }
            }
        }
        coo.push(6, 7, 1.0);
        coo.push(7, 6, 1.0);
        let m = coo.to_csr();
        let g = GridSummary::new(&m, 1);
        let s = optimal_diagonal(&g).unwrap();
        assert_eq!(s.diag_len, vec![3, 3, 2]);
        let e = evaluate(&s, &g, RewardWeights::new(0.5));
        assert_eq!(e.coverage_ratio, 1.0);
    }

    #[test]
    fn dense_matrix_needs_one_block() {
        let mut coo = Coo::new(4, 4);
        for a in 0..4 {
            for b in 0..4 {
                coo.push(a, b, 1.0);
            }
        }
        let g = GridSummary::new(&coo.to_csr(), 1);
        let s = optimal_diagonal(&g).unwrap();
        assert_eq!(s.diag_len, vec![4]);
    }

    #[test]
    fn oracle_is_complete_on_real_datasets() {
        for m in [synth::qm7_like(5828), synth::qh882_like(882)] {
            let r = crate::reorder::reorder(&m, crate::reorder::Reordering::CuthillMckee);
            let g = GridSummary::new(&r.matrix, 2);
            let s = optimal_diagonal(&g).unwrap();
            s.validate(g.n).unwrap();
            let e = evaluate(&s, &g, RewardWeights::new(0.8));
            assert_eq!(e.coverage_ratio, 1.0, "oracle must reach complete coverage");
            assert!(e.area_ratio <= 1.0);
        }
    }

    #[test]
    fn oracle_not_worse_than_any_random_complete_partition_property() {
        check("oracle_optimality", 30, |rng| {
            let dim = 10 + rng.below(40) as usize;
            let mut coo = Coo::new(dim, dim);
            for i in 0..dim {
                coo.push(i, i, 1.0);
            }
            for _ in 0..dim {
                let a = rng.below(dim as u64) as usize;
                let off = 1 + rng.below(4) as usize;
                let b = (a + off).min(dim - 1);
                if a != b {
                    coo.push_sym(b, a, 1.0);
                }
            }
            let m = coo.to_csr();
            let g = GridSummary::new(&m, 1);
            let oracle = optimal_diagonal(&g).unwrap();
            let oracle_area = partition_area(&g, &oracle.diag_len);

            // random complete-coverage candidate: merge oracle's blocks
            // randomly (merging preserves completeness)
            let mut merged: Vec<usize> = Vec::new();
            for &l in &oracle.diag_len {
                if !merged.is_empty() && rng.bool(0.5) {
                    *merged.last_mut().unwrap() += l;
                } else {
                    merged.push(l);
                }
            }
            let cand_area = partition_area(&g, &merged);
            if cand_area < oracle_area {
                return Err(format!(
                    "candidate {merged:?} area {cand_area} beats oracle {:?} area {oracle_area}",
                    oracle.diag_len
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn infeasible_middle_blocks_are_skipped() {
        // anti-diagonal entry forces the full block.
        let mut coo = Coo::new(6, 6);
        coo.push_sym(0, 5, 1.0);
        for i in 0..6 {
            coo.push(i, i, 1.0);
        }
        let g = GridSummary::new(&coo.to_csr(), 1);
        let s = optimal_diagonal(&g).unwrap();
        assert_eq!(s.diag_len, vec![6]);
    }
}
