//! Pure-Rust L2 controller math: fused LSTM cell, per-step FC heads,
//! log-softmax — as reusable step/cache structs shared by the forward
//! mirror and the native training backend.
//!
//! Serves four purposes:
//! 1. cross-validation: integration tests teacher-force the HLO rollout's
//!    sampled actions through this mirror and assert the log-probs agree
//!    to float tolerance (catching ABI drift between aot.py and the Rust
//!    parameter layout);
//! 2. a no-artifacts fallback so every CLI command works before
//!    `make artifacts`;
//! 3. documentation-by-construction of the exact controller math
//!    (gate packing (f,i,g,o), Algo. 1 double-step, fill masking);
//! 4. the forward half of the native trainer: [`LstmCell::step_cached`]
//!    retains per-step intermediates and [`LstmCell::backward`] /
//!    [`head_backward`] invert them, so `agent::native::bptt` can run full
//!    backprop-through-time over exactly this math. Gradients *are*
//!    mirrored — training no longer requires the AOT train_step artifact
//!    (see [`crate::agent::backend`]).
//!
//! Mirrors `python/compile/model.py` exactly.

use crate::runtime::manifest::ControllerEntry;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

/// Controller parameters as named row-major f32 tensors.
pub type Params = BTreeMap<String, Vec<f32>>;

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// One fused LSTM cell bound to its weights: `w` is [(I+H), 4H] row-major
/// over the concatenated input `xh = [x, h_prev]`, `b` is [4H], gate
/// packing (f, i, g, o) — the layout `python/compile/model.py` emits.
pub struct LstmCell<'a> {
    pub w: &'a [f32],
    pub b: &'a [f32],
    pub hidden: usize,
}

/// Intermediates of one [`LstmCell::step_cached`] call, retained for
/// [`LstmCell::backward`]. `c` is the *new* cell state (also the next
/// step's `c_prev`).
#[derive(Clone, Debug)]
pub struct LstmStepCache {
    pub xh: Vec<f32>,
    pub c_prev: Vec<f32>,
    pub f: Vec<f32>,
    pub i: Vec<f32>,
    pub g: Vec<f32>,
    pub o: Vec<f32>,
    pub c: Vec<f32>,
}

impl<'a> LstmCell<'a> {
    pub fn new(w: &'a [f32], b: &'a [f32], hidden: usize) -> LstmCell<'a> {
        debug_assert_eq!(b.len(), 4 * hidden);
        LstmCell { w, b, hidden }
    }

    /// Pre-activations z = xh @ W + b.
    fn preact(&self, xh: &[f32]) -> Vec<f32> {
        let out_dim = 4 * self.hidden;
        debug_assert_eq!(self.w.len(), xh.len() * out_dim);
        let mut z = self.b.to_vec();
        for (r, &xi) in xh.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.w[r * out_dim..(r + 1) * out_dim];
            for (zj, wj) in z.iter_mut().zip(row.iter()) {
                *zj += xi * wj;
            }
        }
        z
    }

    /// One step: returns (h, c).
    pub fn step(&self, xh: &[f32], c_prev: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let z = self.preact(xh);
        let hn = self.hidden;
        let mut h = vec![0.0; hn];
        let mut c = vec![0.0; hn];
        for j in 0..hn {
            let f = sigmoid(z[j]);
            let i = sigmoid(z[hn + j]);
            let g = z[2 * hn + j].tanh();
            let o = sigmoid(z[3 * hn + j]);
            c[j] = f * c_prev[j] + i * g;
            h[j] = o * c[j].tanh();
        }
        (h, c)
    }

    /// Like [`Self::step`], but consumes its inputs into a cache for the
    /// backward pass. Returns (h, cache); the new cell state is `cache.c`.
    pub fn step_cached(&self, xh: Vec<f32>, c_prev: Vec<f32>) -> (Vec<f32>, LstmStepCache) {
        let z = self.preact(&xh);
        let hn = self.hidden;
        let mut f = vec![0.0; hn];
        let mut i = vec![0.0; hn];
        let mut g = vec![0.0; hn];
        let mut o = vec![0.0; hn];
        let mut c = vec![0.0; hn];
        let mut h = vec![0.0; hn];
        for j in 0..hn {
            f[j] = sigmoid(z[j]);
            i[j] = sigmoid(z[hn + j]);
            g[j] = z[2 * hn + j].tanh();
            o[j] = sigmoid(z[3 * hn + j]);
            c[j] = f[j] * c_prev[j] + i[j] * g[j];
            h[j] = o[j] * c[j].tanh();
        }
        (
            h,
            LstmStepCache {
                xh,
                c_prev,
                f,
                i,
                g,
                o,
                c,
            },
        )
    }

    /// Reverse-mode step: `dh`/`dc` are the loss gradients w.r.t. this
    /// step's outputs (h, c). Accumulates weight/bias gradients into
    /// `dw`/`db` and returns (dxh, dc_prev).
    pub fn backward(
        &self,
        cache: &LstmStepCache,
        dh: &[f32],
        dc: &[f32],
        dw: &mut [f32],
        db: &mut [f32],
    ) -> (Vec<f32>, Vec<f32>) {
        let hn = self.hidden;
        let out_dim = 4 * hn;
        let mut dz = vec![0.0f32; out_dim];
        let mut dc_prev = vec![0.0f32; hn];
        for j in 0..hn {
            let (f, i, g, o) = (cache.f[j], cache.i[j], cache.g[j], cache.o[j]);
            let tc = cache.c[j].tanh();
            let d_o = dh[j] * tc;
            // total cell-state gradient: downstream dc plus the h = o·tanh(c) path
            let dcj = dc[j] + dh[j] * o * (1.0 - tc * tc);
            dc_prev[j] = dcj * f;
            dz[j] = dcj * cache.c_prev[j] * f * (1.0 - f);
            dz[hn + j] = dcj * g * i * (1.0 - i);
            dz[2 * hn + j] = dcj * i * (1.0 - g * g);
            dz[3 * hn + j] = d_o * o * (1.0 - o);
        }
        let mut dxh = vec![0.0f32; cache.xh.len()];
        for (r, &x) in cache.xh.iter().enumerate() {
            let wrow = &self.w[r * out_dim..(r + 1) * out_dim];
            let dwrow = &mut dw[r * out_dim..(r + 1) * out_dim];
            let mut acc = 0.0f32;
            for j in 0..out_dim {
                dwrow[j] += x * dz[j];
                acc += wrow[j] * dz[j];
            }
            dxh[r] = acc;
        }
        for (dbj, &dzj) in db.iter_mut().zip(dz.iter()) {
            *dbj += dzj;
        }
        (dxh, dc_prev)
    }
}

/// Log-softmax over one logits row.
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&l| (l - max).exp()).sum::<f32>().ln() + max;
    logits.iter().map(|&l| l - lse).collect()
}

/// Per-step FC head: logits = inp @ w_t + b_t, where `w_t` is
/// [head_in, classes] row-major.
pub fn head(inp: &[f32], w_t: &[f32], b_t: &[f32], classes: usize) -> Vec<f32> {
    let mut out = b_t.to_vec();
    for (i, &xi) in inp.iter().enumerate() {
        for j in 0..classes {
            out[j] += xi * w_t[i * classes + j];
        }
    }
    out
}

/// Reverse-mode of [`head`]: given `dlogits`, accumulates `dw_t`/`db_t`
/// and adds `w_t · dlogits` into `dinp`.
pub fn head_backward(
    inp: &[f32],
    w_t: &[f32],
    dlogits: &[f32],
    dw_t: &mut [f32],
    db_t: &mut [f32],
    dinp: &mut [f32],
) {
    let classes = dlogits.len();
    for (i, &xi) in inp.iter().enumerate() {
        let wrow = &w_t[i * classes..(i + 1) * classes];
        let dwrow = &mut dw_t[i * classes..(i + 1) * classes];
        let mut acc = 0.0f32;
        for (j, &dl) in dlogits.iter().enumerate() {
            dwrow[j] += xi * dl;
            acc += wrow[j] * dl;
        }
        dinp[i] += acc;
    }
    for (dbj, &dl) in db_t.iter_mut().zip(dlogits.iter()) {
        *dbj += dl;
    }
}

/// Action selection policy for [`forward`].
pub enum Select<'a> {
    /// Multinomial sampling with this RNG.
    Sample(&'a mut Pcg64),
    /// Deterministic argmax.
    Greedy,
    /// Teacher-forced: score these given actions (d, f per step).
    Teacher { d: &'a [i32], f: &'a [i32] },
}

/// One-episode rollout result.
#[derive(Debug, Clone)]
pub struct Episode {
    pub d_actions: Vec<i32>,
    pub f_actions: Vec<i32>,
    pub logp: f32,
    pub entropy: f32,
}

/// Run the controller for one episode (batch dim of 1; the native backend
/// fans episodes out across worker threads for throughput).
pub fn forward(entry: &ControllerEntry, params: &Params, mut select: Select) -> Episode {
    let hidden = entry.hidden;
    let t_steps = entry.steps;
    let fill = entry.fill_classes;
    let head_in = if entry.bilstm { 2 * hidden } else { hidden };

    let get = |name: &str| -> &[f32] {
        params
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name}"))
    };
    let cell = LstmCell::new(get("lstm_w"), get("lstm_b"), hidden);

    // BiLSTM auxiliary backward pass over learned embeddings.
    let hb: Vec<Vec<f32>> = if entry.bilstm {
        let emb = get("bwd_emb");
        let bwd_cell = LstmCell::new(get("bwd_w"), get("bwd_b"), hidden);
        let mut h = vec![0.0; hidden];
        let mut c = vec![0.0; hidden];
        let mut rev = Vec::with_capacity(t_steps);
        for t in (0..t_steps).rev() {
            let x = &emb[t * hidden..(t + 1) * hidden];
            let mut xh = x.to_vec();
            xh.extend_from_slice(&h);
            let (h2, c2) = bwd_cell.step(&xh, &c);
            h = h2;
            c = c2;
            rev.push(h.clone());
        }
        rev.reverse();
        rev
    } else {
        Vec::new()
    };

    let mut x = get("x0").to_vec();
    let mut h = vec![0.0f32; hidden];
    let mut c = vec![0.0f32; hidden];
    let mut logp = 0.0f32;
    let mut entropy = 0.0f32;
    let mut d_actions = Vec::with_capacity(t_steps);
    let mut f_actions = Vec::with_capacity(t_steps);

    let fc_d_w = get("fc_d_w");
    let fc_d_b = get("fc_d_b");

    for t in 0..t_steps {
        // --- diagonal decision
        let mut xh = x.clone();
        xh.extend_from_slice(&h);
        let (h1, c1) = cell.step(&xh, &c);
        let head_inp: Vec<f32> = if entry.bilstm {
            h1.iter().chain(hb[t].iter()).cloned().collect()
        } else {
            h1.clone()
        };
        let logits_d = head(
            &head_inp,
            &fc_d_w[t * head_in * 2..(t + 1) * head_in * 2],
            &fc_d_b[t * 2..(t + 1) * 2],
            2,
        );
        let lsm_d = log_softmax(&logits_d);
        let d = match &mut select {
            Select::Sample(rng) => {
                let w: Vec<f64> = lsm_d.iter().map(|&l| (l as f64).exp()).collect();
                rng.multinomial(&w) as i32
            }
            Select::Greedy => argmax(&lsm_d),
            Select::Teacher { d, .. } => d[t],
        };
        logp += lsm_d[d as usize];
        entropy -= lsm_d.iter().map(|&l| l.exp() * l).sum::<f32>();
        d_actions.push(d);

        if fill > 0 {
            // --- fill decision (always computed, masked by d == 0)
            let fc_f_w = get("fc_f_w");
            let fc_f_b = get("fc_f_b");
            let mut xh2 = h1.clone();
            xh2.extend_from_slice(&h1);
            let (h2, c2) = cell.step(&xh2, &c1);
            let head_inp2: Vec<f32> = if entry.bilstm {
                h2.iter().chain(hb[t].iter()).cloned().collect()
            } else {
                h2.clone()
            };
            let logits_f = head(
                &head_inp2,
                &fc_f_w[t * head_in * fill..(t + 1) * head_in * fill],
                &fc_f_b[t * fill..(t + 1) * fill],
                fill,
            );
            let lsm_f = log_softmax(&logits_f);
            let f = match &mut select {
                Select::Sample(rng) => {
                    let w: Vec<f64> = lsm_f.iter().map(|&l| (l as f64).exp()).collect();
                    rng.multinomial(&w) as i32
                }
                Select::Greedy => argmax(&lsm_f),
                Select::Teacher { f, .. } => f[t],
            };
            f_actions.push(f);
            if d == 0 {
                logp += lsm_f[f as usize];
                entropy -= lsm_f.iter().map(|&l| l.exp() * l).sum::<f32>();
                h = h2;
                c = c2;
            } else {
                h = h1;
                c = c1;
            }
        } else {
            f_actions.push(0);
            h = h1;
            c = c1;
        }
        x = h.clone();
    }

    Episode {
        d_actions,
        f_actions,
        logp,
        entropy,
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::params::init_params;

    fn entry(fill: usize, bilstm: bool) -> ControllerEntry {
        // hidden 6, n 5 -> T = 4 decision points
        ControllerEntry::from_dims("test", 5, 6, fill, 1, bilstm)
    }

    #[test]
    fn sample_emits_valid_actions() {
        for (fill, bilstm) in [(0, false), (2, false), (4, false), (2, true)] {
            let e = entry(fill, bilstm);
            let params = init_params(&e, 42);
            let mut rng = Pcg64::seed_from_u64(1);
            let ep = forward(&e, &params, Select::Sample(&mut rng));
            assert_eq!(ep.d_actions.len(), e.steps);
            assert!(ep.d_actions.iter().all(|&d| d == 0 || d == 1));
            if fill > 0 {
                assert!(ep.f_actions.iter().all(|&f| (f as usize) < fill));
            }
            assert!(ep.logp < 0.0);
            assert!(ep.entropy > 0.0);
        }
    }

    #[test]
    fn teacher_forcing_reproduces_sampled_logp() {
        let e = entry(4, false);
        let params = init_params(&e, 7);
        let mut rng = Pcg64::seed_from_u64(2);
        let ep = forward(&e, &params, Select::Sample(&mut rng));
        let scored = forward(
            &e,
            &params,
            Select::Teacher {
                d: &ep.d_actions,
                f: &ep.f_actions,
            },
        );
        assert!((scored.logp - ep.logp).abs() < 1e-5);
        assert_eq!(scored.d_actions, ep.d_actions);
    }

    #[test]
    fn greedy_is_deterministic() {
        let e = entry(2, true);
        let params = init_params(&e, 9);
        let a = forward(&e, &params, Select::Greedy);
        let b = forward(&e, &params, Select::Greedy);
        assert_eq!(a.d_actions, b.d_actions);
        assert_eq!(a.f_actions, b.f_actions);
    }

    #[test]
    fn fill_mask_excludes_fill_logp_when_all_extend() {
        // teacher-force all-extend: fill actions must not affect logp.
        let e = entry(4, false);
        let params = init_params(&e, 11);
        let d = vec![1; e.steps];
        let f0 = vec![0; e.steps];
        let f3 = vec![3; e.steps];
        let a = forward(&e, &params, Select::Teacher { d: &d, f: &f0 });
        let b = forward(&e, &params, Select::Teacher { d: &d, f: &f3 });
        assert!((a.logp - b.logp).abs() < 1e-6);
    }

    #[test]
    fn step_and_step_cached_agree() {
        let e = entry(0, false);
        let params = init_params(&e, 21);
        let cell = LstmCell::new(&params["lstm_w"], &params["lstm_b"], e.hidden);
        let xh: Vec<f32> = (0..2 * e.hidden).map(|i| (i as f32) * 0.05 - 0.3).collect();
        let c_prev: Vec<f32> = (0..e.hidden).map(|i| (i as f32) * 0.1 - 0.25).collect();
        let (h_a, c_a) = cell.step(&xh, &c_prev);
        let (h_b, cache) = cell.step_cached(xh.clone(), c_prev.clone());
        assert_eq!(h_a, h_b);
        assert_eq!(c_a, cache.c);
        assert_eq!(cache.xh, xh);
        assert_eq!(cache.c_prev, c_prev);
    }
}
