//! Multi-tenant network serving tier: a TCP front end over a registry of
//! deployed bundles, with admission control and live hot-swap.
//!
//! The stdin `serve` loop amortizes one graph's mapping cost over many
//! `y = Ax` queries; this tier amortizes it over many *graphs and
//! clients* at once. A [`DeploymentRegistry`] owns N loaded bundles, each
//! serving behind one shared worker pool; a [`NetServer`] accepts TCP
//! connections (one handler thread each, capped) and routes NDJSON
//! requests by deployment id. The `serve-net` CLI subcommand wires the
//! two together.
//!
//! # Wire protocol
//!
//! One JSON object per `\n`-terminated line, one response line per
//! request line, on the same connection, in order. Blank lines are
//! skipped; a line over the configured byte cap is drained and answered
//! with a `parse` error (the connection stays usable). All error objects
//! are exactly the stdin loop's dialect
//! (`{"kind": <api::Error::kind()>, "message": ...}`) — both transports
//! are built on [`crate::api::dispatch`].
//!
//! **Tenant requests** name a deployment id and carry one vector or an
//! explicit batch, with an optional pre-execution deadline budget:
//!
//! ```text
//! → {"tenant":"graphA","id":1,"x":[...dim floats...]}
//! ← {"tenant":"graphA","id":1,"y":[...]}
//! → {"tenant":"graphA","id":2,"xs":[[...],[...]],"deadline_ms":50}
//! ← {"tenant":"graphA","id":2,"ys":[[...],[...]]}
//! ← {"tenant":"graphA","id":3,"error":{"kind":"busy","message":...}}
//! ```
//!
//! Rejections are always typed error *responses*, never dropped
//! connections: `busy` when the tenant's bounded queue is at its depth
//! limit (admission happens before any execution), `deadline` when the
//! request's `deadline_ms` budget expired before execution began,
//! `validate` for unknown tenants (the message names the deployed ids)
//! and malformed vectors (length mismatches name both lengths).
//!
//! **Algorithm requests** run a whole iterative graph algorithm
//! ([`crate::algo`]) against a tenant's mapped plan — the request kinds,
//! parameters (and their defaults), payloads, and the embedded `trace`
//! object are exactly the stdin loop's, documented in
//! [`crate::api::dispatch::parse_algo`]:
//!
//! ```text
//! → {"tenant":"graphA","id":4,"pagerank":{"damping":0.85,"tol":1e-9}}
//! ← {"tenant":"graphA","id":4,"pagerank":{"scores":[...],"trace":{...}}}
//! → {"tenant":"graphA","id":5,"bfs":{"source":0}}
//! ← {"tenant":"graphA","id":5,"bfs":{"levels":[...],"reached":..,"trace":{...}}}
//! → {"tenant":"graphA","id":6,"sssp":{"source":0,"chunk":64}}
//! ← {"tenant":"graphA","id":6,"sssp":{"dist":[...],"reached":..,"trace":{...}}}
//! → {"tenant":"graphA","id":7,"gcn":{"x":[[...],...],"layers":[{"out_dim":16}]}}
//! ← {"tenant":"graphA","id":7,"gcn":{"features":[[...],...],"trace":{...}}}
//! ```
//!
//! An algorithm run holds one admission slot for its whole iteration
//! loop and counts once in `served`; `-1` encodes "unreachable" on the
//! wire (BFS level, SSSP distance). A run that exhausts its iteration
//! cap without meeting its tolerance is a typed `no_converge` error
//! whose message reports the iterations and final residual; bad
//! parameters are `validate` errors naming the offending field. Both
//! objects are byte-identical to the stdin loop's for the same request.
//!
//! **Update requests** mutate a tenant's graph live ([`crate::delta`]):
//! each edge triple is `[row, col, weight]` in original node ids, weight
//! `0` deletes the edge, a weight on an existing edge reweights it. The
//! first update attaches a delta engine over the tenant's current
//! generation; afterwards every `x`/`xs` answer is served as
//! `y = (A ± Δ)x` — base plan plus the exact pending overlay — so
//! updates are visible to the very next query:
//!
//! ```text
//! → {"tenant":"graphA","id":8,"update":{"edges":[[3,9,1.5],[3,4,0]]}}
//! ← {"tenant":"graphA","id":8,"update":{"applied":2,"pending":2,
//!      "generation":0}}
//! ```
//!
//! `pending` counts overlay entries not yet folded into the arena;
//! `generation` is the delta engine's remap counter. With `serve-net
//! --remap-after N`, the update that reaches N pending updates folds the
//! overlay automatically before acking (the ack then reports the fresh
//! generation and `pending: 0`). Delta-mode caveats: MVMs served through
//! the overlay bypass an armed fault harness, and algorithm requests run
//! on the last *folded* plan (pending overlay edges become visible to
//! them after the next remap).
//!
//! **Admin requests** query or mutate the registry:
//!
//! ```text
//! → {"admin":"stats"}
//! ← {"admin":"stats","stats":{"graphA":{"served":..,"rps":..,
//!      "nnz_per_s":..,"inflight":..,"queue_depth":..,
//!      "rejected_busy":..,"rejected_deadline":..,"generation":..,
//!      "wall_s":..,"uptime_s":..,
//!      "algo":{"pagerank":..,"bfs":..,"sssp":..,"gcn":..,"mvms":..}},..}}
//! → {"admin":{"reload":{"id":"graphA","bundle":"remapped.json"}}}
//! ← {"admin":"reload","id":"graphA","generation":2,"dim":10000}
//! → {"admin":{"remap":{"id":"graphA"}}}
//! ← {"admin":"remap","id":"graphA","generation":2,"windows":13,
//!      "reused_windows":11,"cache_hit_rate":0.85,"carried_updates":4,
//!      "wall_s":0.4}
//! → {"admin":{"inject":{"id":"graphA","bank":0,"kind":"stuck0",
//!      "rate":0.05,"seed":7}}}
//! ← {"admin":"inject","id":"graphA","generation":1,"cells_changed":..,
//!      "programs":[..]}
//! → {"admin":{"repair":{"id":"graphA"}}}
//! ← {"admin":"repair","id":"graphA","generation":2}
//! ```
//!
//! `remap` folds a dynamic tenant's pending updates into a fresh arena:
//! only delta-touched windows rerun controller inference (the engine's
//! persistent scheme cache serves the untouched ones — `reused_windows`
//! of `windows` in the ack), and the folded deployment is installed as
//! the tenant's next generation exactly like a bundle reload (rate
//! window restarts, in-flight requests finish on the old entry). A
//! fault-armed registry re-arms a fresh harness over the folded arena.
//! Each dynamic tenant's stats object also gains a `"delta"` block:
//! `updates`, `pending`, `remaps`, `generation`.
//!
//! # Fault tolerance on the wire
//!
//! When the registry arms a fault harness ([`RegistryOptions::fault`],
//! CLI `serve-net --fault-harness`), three surfaces change — all
//! backwards-compatible additions:
//!
//! - **`degraded` responses.** A tenant answer computed while the
//!   harness is (or just became) degraded carries `"degraded":true`
//!   alongside `y`/`ys`/the algorithm payload. The answer is still
//!   exact — quarantined rows are served by the digital host-CSR
//!   reference — the flag only says the analog arena did not produce it
//!   alone. Healthy answers omit the key entirely.
//! - **`health` in stats.** Each fault-armed tenant's stats object gains
//!   a `"health"` block: `armed`, `degraded`, `generation` (fault-epoch
//!   counter, not the hot-swap generation), `faulty_cells`,
//!   `quarantined_programs`, `quarantined_rows`, `failed_banks`,
//!   `verify_checks`, `verify_detections`, `scrubs`, `scrub_detections`,
//!   `repairs`, `degraded_served` ([`crate::api::dispatch::health_json`]).
//! - **`inject` / `repair` admin verbs.** `inject` corrupts one bank of
//!   the named tenant under the deterministic device-fault model
//!   ([`crate::fault::FaultKind`]: `stuck0`, `stuck1`, `drift`,
//!   `outage`; `rate` defaults to 0.05, `seed` to 0) and acks with what
//!   it corrupted — detection is deliberately left to the serving-path
//!   checksums and scrub probes. `repair` re-programs quarantined work
//!   onto healthy banks and acks with the fresh fault-epoch generation.
//!   Both are `validate` errors when the tenant has no armed harness.
//!
//! A connection idle past `serve-net --read-timeout-ms` is answered with
//! a typed `timeout` error line and closed; a request that panics the
//! execution path is answered with a typed `internal` error echoing the
//! request id, and the connection keeps serving.
//!
//! `reload` is the live hot-swap: the bundle is loaded from disk outside
//! any lock, then installed with an atomic `Arc` swap. In-flight requests
//! finish on the generation they were admitted against; requests arriving
//! after the ack are served by the new one. The serving invariant — every
//! socket answer is bit-identical to [`crate::api::Deployment::mvm`] on
//! the generation that served it — holds across the swap. A reload also
//! restarts the tenant's rate window: `rps` and `nnz_per_s` in `stats`
//! are normalized by the *current generation's* uptime (its `wall_s`),
//! while `served`, `uptime_s`, and the `algo` counters stay cumulative
//! across generations.
//!
//! # Pieces
//!
//! - [`DeploymentRegistry`] / [`Tenant`] / [`TenantEntry`] — ownership,
//!   routing, admission, counters, hot-swap ([`registry`]).
//! - [`NetServer`] / [`NetOptions`] — the accept loop and per-connection
//!   handlers ([`server`]).
//! - [`run_net_bench`] — the self-checking concurrent load driver behind
//!   `serve-net --bench` and the CI `net-smoke` job ([`bench`]).
//! - [`crate::fault::run_fault_bench`] — the chaos driver behind
//!   `fault-bench` and the CI `fault-smoke` job: mid-stream injection
//!   under concurrent clients, every response oracle-checked.
//! - [`crate::delta::run_delta_bench`] — the dynamic-graph driver behind
//!   `delta-bench` and the CI `delta-smoke` job: concurrent updaters and
//!   queriers, every answer checked against a mutating host-CSR oracle.

pub mod bench;
pub mod registry;
pub mod server;

pub use bench::{run_net_bench, NetBenchOptions, NetBenchReport};
pub use registry::{AdmitGuard, DeploymentRegistry, RegistryOptions, Tenant, TenantEntry};
pub use server::{NetOptions, NetServer, CONN_CAP_TENANT};
