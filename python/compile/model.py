"""L2: the AutoGMap controller (LSTM + per-step FC heads) in JAX.

Implements Algo. 1 (sampling rollout) and Algo. 2 (REINFORCE with baseline,
here with the Adam update fused in) as two pure functions that
``python/compile/aot.py`` lowers to HLO text for the Rust coordinator:

  rollout(params, key)   -> d_actions [B,T] i32, f_actions [B,T] i32,
                            logp [B] f32, entropy [B] f32
  train_step(params, opt, d_actions, f_actions, advantage, lr, ent_coef)
                         -> params', opt', loss, mean_logp

Model structure (paper §V-A):
  - input at decision point t is the previous LSTM *output* (Algo. 1
    line 9: ``inputs <- output``), so input size I = hidden size H; the
    initial input x0 is a learned parameter;
  - per-decision-point FC heads ("the ith diagonal fcs output"), stacked
    as [T, ...] arrays and indexed by the scan step;
  - the fill decision runs a *second* LSTM step whose input is the
    diagonal step's output, exactly Algo. 1 lines 11-18; the fill branch
    is always computed and masked by ``d == 0`` (semantically identical to
    the paper's conditional, but fixed-shape for AOT);
  - optional BiLSTM ablation: a second LSTM consumes learned per-step
    embeddings in *reverse* order (the only causal reading of the paper's
    BiLSTM — see DESIGN.md §5) and its hidden state is concatenated before
    each head.

The sampling rollout calls the L1 Pallas kernel (kernels.lstm_cell); the
train step recomputes log-probs with the numerically identical pure-jnp
cell (kernels.ref.lstm_cell_ref) because pallas_call has no AD rule — the
two are asserted allclose in python/tests.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels.lstm_cell import lstm_cell
from compile.kernels.ref import lstm_cell_ref


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Static shape configuration for one experiment."""

    name: str
    #: grid cells on the diagonal; T = n - 1 decision points.
    n: int
    #: LSTM hidden size (paper Table III: H = 10).
    hidden: int
    #: fill-head classes: 0 = no fill head, 2 = fixed fill (binary),
    #: >2 = dynamic fill with `fill_classes` grades.
    fill_classes: int
    #: episodes sampled per rollout call (batched REINFORCE, Eq. 20 M).
    batch: int
    #: BiLSTM ablation.
    bilstm: bool = False

    @property
    def steps(self) -> int:
        return self.n - 1

    @property
    def head_in(self) -> int:
        return 2 * self.hidden if self.bilstm else self.hidden


# ---------------------------------------------------------------------------
# parameters


def param_spec(cfg: ControllerConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the AOT ABI with the Rust side."""
    H, T, F = cfg.hidden, cfg.steps, cfg.fill_classes
    spec = [
        ("x0", (H,)),
        ("lstm_w", (2 * H, 4 * H)),
        ("lstm_b", (4 * H,)),
    ]
    if cfg.bilstm:
        spec += [
            ("bwd_emb", (T, H)),
            ("bwd_w", (2 * H, 4 * H)),
            ("bwd_b", (4 * H,)),
        ]
    spec += [
        ("fc_d_w", (T, cfg.head_in, 2)),
        ("fc_d_b", (T, 2)),
    ]
    if F > 0:
        spec += [
            ("fc_f_w", (T, cfg.head_in, F)),
            ("fc_f_b", (T, F)),
        ]
    return spec


def init_params(cfg: ControllerConfig, key) -> dict:
    """Uniform(-0.1, 0.1) init, matching the classic NAS-controller setup."""
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        params[name] = jax.random.uniform(sub, shape, jnp.float32, -0.1, 0.1)
    return params


# ---------------------------------------------------------------------------
# shared forward machinery


def _backward_states(cfg: ControllerConfig, params, batch: int, cell):
    """BiLSTM auxiliary pass: backward LSTM over learned embeddings.

    Returns hb [T, B, H] where hb[t] is the backward hidden state aligned
    with decision point t.
    """
    H = cfg.hidden
    emb = params["bwd_emb"]  # [T, H]

    def step(carry, e):
        h, c = carry
        x = jnp.broadcast_to(e[None, :], (batch, H))
        h, c = cell(x, h, c, params["bwd_w"], params["bwd_b"])
        return (h, c), h

    init = (jnp.zeros((batch, H)), jnp.zeros((batch, H)))
    # consume embeddings in reverse order; outputs come back reversed
    _, hb_rev = jax.lax.scan(step, init, emb[::-1])
    return hb_rev[::-1]  # [T, B, H]


def _controller_scan(cfg: ControllerConfig, params, cell, choose_d, choose_f):
    """Core double-step scan shared by rollout (sampling) and train
    (teacher forcing).

    ``choose_d(t, logsm) -> action [B] i32`` and likewise ``choose_f``.

    Returns (d_actions [T,B], f_actions [T,B], logp [B], entropy [B]).
    """
    H, T, B, F = cfg.hidden, cfg.steps, cfg.batch, cfg.fill_classes

    hb = (
        _backward_states(cfg, params, B, cell)
        if cfg.bilstm
        else jnp.zeros((T, B, 0))
    )

    xs = {
        "t": jnp.arange(T),
        "fc_d_w": params["fc_d_w"],
        "fc_d_b": params["fc_d_b"],
        "hb": hb,
    }
    if F > 0:
        xs["fc_f_w"] = params["fc_f_w"]
        xs["fc_f_b"] = params["fc_f_b"]

    def head(h, w, b, hb_t):
        inp = jnp.concatenate([h, hb_t], axis=-1) if cfg.bilstm else h
        return inp @ w + b[None, :]

    def step(carry, x_t):
        x, h, c, logp, ent = carry
        t = x_t["t"]

        # --- diagonal decision (Algo. 1 lines 3-9)
        h1, c1 = cell(x, h, c, params["lstm_w"], params["lstm_b"])
        logits_d = head(h1, x_t["fc_d_w"], x_t["fc_d_b"], x_t["hb"])
        logsm_d = jax.nn.log_softmax(logits_d, axis=-1)
        d = choose_d(t, logsm_d)  # [B] int32
        logp = logp + jnp.take_along_axis(logsm_d, d[:, None], axis=-1)[:, 0]
        ent = ent - jnp.sum(jnp.exp(logsm_d) * logsm_d, axis=-1)

        if F > 0:
            # --- fill decision (Algo. 1 lines 10-18), masked by d == 0
            h2, c2 = cell(h1, h1, c1, params["lstm_w"], params["lstm_b"])
            logits_f = head(h2, x_t["fc_f_w"], x_t["fc_f_b"], x_t["hb"])
            logsm_f = jax.nn.log_softmax(logits_f, axis=-1)
            f = choose_f(t, logsm_f)  # [B] int32
            mask = (d == 0).astype(jnp.float32)
            logp_f = jnp.take_along_axis(logsm_f, f[:, None], axis=-1)[:, 0]
            logp = logp + mask * logp_f
            ent = ent - mask * jnp.sum(jnp.exp(logsm_f) * logsm_f, axis=-1)
            mb = mask[:, None]
            h_next = mb * h2 + (1.0 - mb) * h1
            c_next = mb * c2 + (1.0 - mb) * c1
        else:
            f = jnp.zeros_like(d)
            h_next, c_next = h1, c1

        # Algo. 1 line 9/18: inputs <- output of the last executed step
        x_next = h_next
        return (x_next, h_next, c_next, logp, ent), (d, f)

    x0 = jnp.broadcast_to(params["x0"][None, :], (B, H))
    init = (
        x0,
        jnp.zeros((B, H)),
        jnp.zeros((B, H)),
        jnp.zeros((B,)),
        jnp.zeros((B,)),
    )
    (_, _, _, logp, ent), (d_seq, f_seq) = jax.lax.scan(step, init, xs)
    return d_seq, f_seq, logp, ent


# ---------------------------------------------------------------------------
# rollout (sampling) — Algo. 1


def rollout(cfg: ControllerConfig, params, key):
    """Sample B episodes. Returns (d [B,T] i32, f [B,T] i32, logp [B],
    entropy [B])."""
    T = cfg.steps
    kd, kf = jax.random.split(key)
    kds = jax.random.split(kd, T)
    kfs = jax.random.split(kf, T)

    def choose_d(t, logsm):
        return jax.random.categorical(kds[t], logsm, axis=-1).astype(jnp.int32)

    def choose_f(t, logsm):
        return jax.random.categorical(kfs[t], logsm, axis=-1).astype(jnp.int32)

    d_seq, f_seq, logp, ent = _controller_scan(
        cfg, params, lstm_cell, choose_d, choose_f
    )
    return (
        jnp.transpose(d_seq).astype(jnp.int32),
        jnp.transpose(f_seq).astype(jnp.int32),
        logp,
        ent,
    )


def greedy_rollout(cfg: ControllerConfig, params):
    """Deterministic argmax decode (evaluation mode)."""

    def choose(_, logsm):
        return jnp.argmax(logsm, axis=-1).astype(jnp.int32)

    d_seq, f_seq, logp, ent = _controller_scan(cfg, params, lstm_cell, choose, choose)
    return jnp.transpose(d_seq), jnp.transpose(f_seq), logp, ent


# ---------------------------------------------------------------------------
# teacher-forced log-prob + REINFORCE/Adam train step — Algo. 2


def teacher_logp(cfg: ControllerConfig, params, d_actions, f_actions):
    """Log-probability (and entropy) of given action sequences.

    d_actions/f_actions: [B, T] int32. Uses the jnp reference cell so the
    whole computation is differentiable.
    """
    d_t = jnp.transpose(d_actions)  # [T, B]
    f_t = jnp.transpose(f_actions)

    def choose_d(t, _):
        return d_t[t]

    def choose_f(t, _):
        return f_t[t]

    _, _, logp, ent = _controller_scan(cfg, params, lstm_cell_ref, choose_d, choose_f)
    return logp, ent


def adam_init(params):
    return {
        "m": jax.tree_util.tree_map(jnp.zeros_like, params),
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def train_step(cfg: ControllerConfig, params, opt, d_actions, f_actions, advantage, lr, ent_coef):
    """One REINFORCE step: loss = -mean(adv · logp) - ent_coef · mean(H).

    The advantage (reward - EMA baseline, Algo. 2 lines 1-2) is computed by
    the Rust environment and passed in. Returns (params', opt', loss,
    mean_logp).
    """

    def loss_fn(p):
        logp, ent = teacher_logp(cfg, p, d_actions, f_actions)
        loss = -jnp.mean(advantage * logp) - ent_coef * jnp.mean(ent)
        return loss, jnp.mean(logp)

    (loss, mean_logp), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

    b1, b2, eps = 0.9, 0.999, 1e-8
    t = opt["t"] + 1
    tf = t.astype(jnp.float32)

    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)

    def apply(p, m_, v_):
        mhat = m_ / (1 - b1**tf)
        vhat = v_ / (1 - b2**tf)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps)

    new_params = jax.tree_util.tree_map(apply, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}, loss, mean_logp


# ---------------------------------------------------------------------------
# flat ABI used by aot.py (params as an ordered list of arrays)


def params_to_list(cfg: ControllerConfig, params: dict) -> list:
    return [params[name] for name, _ in param_spec(cfg)]


def params_from_list(cfg: ControllerConfig, flat) -> dict:
    names = [name for name, _ in param_spec(cfg)]
    assert len(flat) == len(names)
    return dict(zip(names, flat))


def rollout_flat(cfg: ControllerConfig):
    """Flat-ABI rollout: (param_0..param_k, key u32[2]) -> 4 outputs."""

    def fn(*args):
        *flat, key = args
        params = params_from_list(cfg, list(flat))
        return rollout(cfg, params, key)

    return fn


def greedy_flat(cfg: ControllerConfig):
    """Flat-ABI greedy decode: (param_0..param_k) -> 4 outputs."""

    def fn(*args):
        params = params_from_list(cfg, list(args))
        return greedy_rollout(cfg, params)

    return fn


def train_flat(cfg: ControllerConfig):
    """Flat-ABI train step:
    (param_0.., m_0.., v_0.., t, d, f, adv, lr, ent) ->
    (param'_0.., m'_0.., v'_0.., t', loss, mean_logp)."""
    k = len(param_spec(cfg))

    def fn(*args):
        p = params_from_list(cfg, list(args[:k]))
        m = params_from_list(cfg, list(args[k : 2 * k]))
        v = params_from_list(cfg, list(args[2 * k : 3 * k]))
        t, d_actions, f_actions, advantage, lr, ent_coef = args[3 * k :]
        opt = {"m": m, "v": v, "t": t}
        new_p, new_opt, loss, mean_logp = train_step(
            cfg, p, opt, d_actions, f_actions, advantage, lr, ent_coef
        )
        if cfg.fill_classes == 0:
            # f_actions is semantically unused for diagonal-only configs;
            # anchor it so XLA does not drop the parameter and change the
            # call ABI (Rust always passes the full input list).
            loss = loss + 0.0 * jnp.sum(f_actions.astype(jnp.float32))
        return (
            *params_to_list(cfg, new_p),
            *params_to_list(cfg, new_opt["m"]),
            *params_to_list(cfg, new_opt["v"]),
            new_opt["t"],
            loss,
            mean_logp,
        )

    return fn
