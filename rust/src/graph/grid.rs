//! Grid coarsening of a sparse matrix.
//!
//! The paper reduces problem scale by partitioning the D×D matrix into a
//! grid of k×k cells ("Empirically, we set the grid size of qh882 and
//! qh1484 to be 32"); the agent then decides at grid granularity. This
//! module aggregates per-cell non-zero counts and builds a 2-D prefix sum
//! so the environment can score any rectangle of grid cells in O(1).

use crate::graph::sparse::Csr;

/// Grid-level summary of a sparse matrix.
#[derive(Clone, Debug)]
pub struct GridSummary {
    /// Matrix dimension (square).
    pub dim: usize,
    /// Grid cell side length in matrix units.
    pub grid: usize,
    /// Number of grid cells per side: ⌈dim / grid⌉.
    pub n: usize,
    /// Per-cell nnz counts, row-major n×n.
    pub cell_nnz: Vec<u32>,
    /// Inclusion-style 2-D prefix sums, (n+1)×(n+1): pre[i][j] = nnz in
    /// grid rows [0,i) × grid cols [0,j).
    pre: Vec<u64>,
    /// Total non-zeros of the underlying matrix.
    pub total_nnz: usize,
    /// Exact matrix-unit nnz prefix (for metrics that need matrix-level
    /// counts of truncated trailing blocks we reuse the csr itself).
    pub last_cell: usize,
}

/// Inclusion-style 2-D prefix sums over row-major n×n cell counts:
/// out[i][j] = Σ cells in rows [0,i) × cols [0,j), shape (n+1)×(n+1).
fn prefix_sums(cell_nnz: &[u32], n: usize) -> Vec<u64> {
    let mut pre = vec![0u64; (n + 1) * (n + 1)];
    for i in 0..n {
        for j in 0..n {
            pre[(i + 1) * (n + 1) + (j + 1)] = cell_nnz[i * n + j] as u64
                + pre[i * (n + 1) + (j + 1)]
                + pre[(i + 1) * (n + 1) + j]
                - pre[i * (n + 1) + j];
        }
    }
    pre
}

impl GridSummary {
    pub fn new(m: &Csr, grid: usize) -> GridSummary {
        assert_eq!(m.rows, m.cols, "grid summary expects a square matrix");
        assert!(grid > 0, "grid size must be positive");
        let dim = m.rows;
        let n = dim.div_ceil(grid);
        let mut cell_nnz = vec![0u32; n * n];
        for r in 0..dim {
            let gr = r / grid;
            for &c in m.row(r) {
                cell_nnz[gr * n + c / grid] += 1;
            }
        }
        let pre = prefix_sums(&cell_nnz, n);
        GridSummary {
            dim,
            grid,
            n,
            cell_nnz,
            pre,
            total_nnz: m.nnz(),
            last_cell: dim - (n - 1) * grid,
        }
    }

    /// nnz inside grid-cell rectangle rows [r0,r1) × cols [c0,c1) (clamped).
    pub fn nnz_rect(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> u64 {
        let (r0, r1) = (r0.min(self.n), r1.min(self.n));
        let (c0, c1) = (c0.min(self.n), c1.min(self.n));
        if r0 >= r1 || c0 >= c1 {
            return 0;
        }
        let w = self.n + 1;
        self.pre[r1 * w + c1] + self.pre[r0 * w + c0]
            - self.pre[r0 * w + c1]
            - self.pre[r1 * w + c0]
    }

    /// Matrix-unit side length of a run of `len` grid cells starting at
    /// grid index `g0` — the trailing cell is truncated at the matrix edge
    /// (this is why Table IV block sizes end in 18, 82, 50, 44, 12).
    pub fn span_units(&self, g0: usize, len: usize) -> usize {
        let start = g0 * self.grid;
        let end = ((g0 + len) * self.grid).min(self.dim);
        end.saturating_sub(start)
    }

    /// Matrix-unit area of the square block covering grid cells [g0, g0+len).
    pub fn block_area(&self, g0: usize, len: usize) -> u64 {
        let s = self.span_units(g0, len) as u64;
        s * s
    }

    /// Matrix-unit area of the rectangle rows [r0,r1) × cols [c0,c1) in grid cells.
    pub fn rect_area(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> u64 {
        let h = self.span_units(r0, r1.saturating_sub(r0)) as u64;
        let w = self.span_units(c0, c1.saturating_sub(c0)) as u64;
        h * w
    }

    /// Grid summary of the diagonal window covering grid cells
    /// [g0, g0+len)² — what the mapper's per-window controller sees. Built
    /// from the already-aggregated cell counts (no submatrix extraction),
    /// it is identical to `GridSummary::new` on the extracted sub-block:
    /// window starts are grid-aligned, so cells map one-to-one, and the
    /// trailing cell is truncated only when the window touches the matrix
    /// edge.
    pub fn window(&self, g0: usize, len: usize) -> GridSummary {
        assert!(len >= 1 && g0 + len <= self.n, "window exceeds the grid");
        let dim = self.span_units(g0, len);
        let mut cell_nnz = vec![0u32; len * len];
        for i in 0..len {
            for j in 0..len {
                cell_nnz[i * len + j] = self.cell_nnz[(g0 + i) * self.n + (g0 + j)];
            }
        }
        let pre = prefix_sums(&cell_nnz, len);
        let total_nnz = pre[len * (len + 1) + len] as usize;
        GridSummary {
            dim,
            grid: self.grid,
            n: len,
            cell_nnz,
            pre,
            total_nnz,
            last_cell: dim - (len - 1) * self.grid,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg64;

    fn random_sym(rng: &mut Pcg64, dim: usize, edges: usize) -> Csr {
        let mut coo = Coo::new(dim, dim);
        for _ in 0..edges {
            let r = rng.below(dim as u64) as usize;
            let c = rng.below(dim as u64) as usize;
            coo.push_sym(r.max(c), r.min(c), 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn cell_counts_match_direct() {
        let mut rng = Pcg64::seed_from_u64(1);
        let m = random_sym(&mut rng, 37, 60); // 37 not divisible by grid 8
        let g = GridSummary::new(&m, 8);
        assert_eq!(g.n, 5);
        for gr in 0..g.n {
            for gc in 0..g.n {
                let direct =
                    m.nnz_in_rect(gr * 8, (gr + 1) * 8, gc * 8, (gc + 1) * 8) as u32;
                assert_eq!(g.cell_nnz[gr * g.n + gc], direct);
            }
        }
        assert_eq!(g.nnz_rect(0, g.n, 0, g.n), m.nnz() as u64);
    }

    #[test]
    fn prefix_rect_matches_brute_force_property() {
        check("grid_prefix_rect", 40, |rng| {
            let dim = 16 + rng.below(64) as usize;
            let grid = 1 + rng.below(9) as usize;
            let m = random_sym(rng, dim, dim * 2);
            let g = GridSummary::new(&m, grid);
            for _ in 0..20 {
                let r0 = rng.below(g.n as u64 + 1) as usize;
                let r1 = rng.below(g.n as u64 + 1) as usize;
                let c0 = rng.below(g.n as u64 + 1) as usize;
                let c1 = rng.below(g.n as u64 + 1) as usize;
                let (r0, r1) = (r0.min(r1), r0.max(r1));
                let (c0, c1) = (c0.min(c1), c0.max(c1));
                let direct =
                    m.nnz_in_rect(r0 * grid, r1 * grid, c0 * grid, c1 * grid) as u64;
                if g.nnz_rect(r0, r1, c0, c1) != direct {
                    return Err(format!(
                        "rect ({r0},{r1})x({c0},{c1}) grid {grid} dim {dim}: prefix {} != direct {direct}",
                        g.nnz_rect(r0, r1, c0, c1)
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn span_truncates_at_edge() {
        let mut rng = Pcg64::seed_from_u64(2);
        let m = random_sym(&mut rng, 882, 1000);
        let g = GridSummary::new(&m, 32);
        assert_eq!(g.n, 28); // ceil(882/32)
        assert_eq!(g.span_units(0, 1), 32);
        assert_eq!(g.span_units(27, 1), 882 - 27 * 32); // = 18
        assert_eq!(g.span_units(26, 2), 882 - 26 * 32); // truncated run = 50
        assert_eq!(g.block_area(27, 1), 18 * 18);
    }

    #[test]
    fn window_matches_full_summary() {
        let mut rng = Pcg64::seed_from_u64(9);
        let m = random_sym(&mut rng, 70, 150); // 70 = 8*8 + 6: truncated edge
        let g = GridSummary::new(&m, 8);
        assert_eq!(g.n, 9);
        for (g0, len) in [(0usize, 3usize), (2, 4), (5, 4), (0, 9)] {
            let w = g.window(g0, len);
            assert_eq!(w.n, len);
            assert_eq!(w.grid, 8);
            assert_eq!(w.dim, g.span_units(g0, len));
            assert_eq!(
                w.total_nnz as u64,
                g.nnz_rect(g0, g0 + len, g0, g0 + len),
                "window ({g0},{len}) total"
            );
            // every sub-rectangle agrees with the full summary
            for r0 in 0..=len {
                for r1 in r0..=len {
                    assert_eq!(
                        w.nnz_rect(r0, r1, 0, len),
                        g.nnz_rect(g0 + r0, g0 + r1, g0, g0 + len)
                    );
                }
            }
            // areas agree too (trailing truncation included)
            assert_eq!(w.block_area(len - 1, 1), g.block_area(g0 + len - 1, 1));
        }
    }

    #[test]
    fn degenerate_rects_are_zero() {
        let mut rng = Pcg64::seed_from_u64(3);
        let m = random_sym(&mut rng, 20, 30);
        let g = GridSummary::new(&m, 4);
        assert_eq!(g.nnz_rect(3, 3, 0, 5), 0);
        assert_eq!(g.nnz_rect(4, 2, 0, 5), 0);
        assert_eq!(g.nnz_rect(0, 99, 0, 99), m.nnz() as u64); // clamped
    }
}
