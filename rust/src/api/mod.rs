//! The public serving API: build a deployment once, save it as a bundle,
//! reload it anywhere, serve it forever.
//!
//! Everything below this module is a pipeline stage (graph → reorder →
//! map → compile → fleet → execute) that entry points used to wire by
//! hand, in two parallel flavors — the engine's flat plans and the
//! mapper's composites. This module is the single front door over both:
//!
//! - [`DeploymentBuilder`] — declare a [`Source`] (`.mtx` file, synthetic
//!   R-MAT graph, in-memory CSR), a [`Strategy`] (direct controller /
//!   hierarchical mapper / fixed-block baseline), and kernel/fleet/worker
//!   knobs; `build()` runs the pipeline.
//! - [`Deployment`] — owns the compiled [`DeployedPlan`] (flat or
//!   composite, both behind the unified [`crate::engine::Servable`]
//!   trait), the fleet assignment, the reordering permutation, and
//!   [`Provenance`]. Serves in *original* node ids.
//! - **Bundles** — [`Deployment::save`] / [`Deployment::load`] move a
//!   deployment through one self-contained versioned JSON file
//!   (embedding the v3 plan arena), so the mapping cost is paid once and
//!   reload is a pure load + execute path that serves bit-identically.
//! - [`serve_loop`] — the long-running NDJSON request/response loop the
//!   `serve` CLI subcommand wraps around stdin/stdout, with typed
//!   [`Error`]s surfaced as machine-readable error responses instead of
//!   process exits.
//! - [`dispatch`] — the transport-agnostic request-dispatch core (bounded
//!   NDJSON framing, request validation, permuted execution, the shared
//!   error wire format, deadline checks). Both `serve_loop` and the
//!   multi-tenant TCP tier in [`crate::net`] are thin loops over it.
//!
//! The 5-line flow:
//!
//! ```no_run
//! use autogmap::api::{Deployment, DeploymentBuilder, Source, Strategy};
//! # fn main() -> autogmap::api::Result<()> {
//! let dep = DeploymentBuilder::new(
//!     Source::Rmat { nodes: 10_000, degree: 8, seed: 42 },
//!     Strategy::Hierarchical { controller: "qh882_dyn4".into(), overlap: 4 },
//! ).build()?;
//! dep.save(std::path::Path::new("bundle.json"))?;
//! let served = Deployment::load(std::path::Path::new("bundle.json"))?;
//! let y = served.mvm(&vec![1.0; 10_000])?; // or serve_loop / executor()
//! # let _ = y; Ok(()) }
//! ```

pub mod deploy;
pub mod dispatch;
pub mod error;
pub mod serve;

pub use deploy::{
    DeployedPlan, Deployment, DeploymentBuilder, KernelChoice, Provenance, Source, Strategy,
    BUNDLE_VERSION,
};
pub use error::{Error, Result};
pub use serve::{serve_loop, ServeOptions, ServeReport};
