//! Bench: PJRT artifact latency — rollout sampling, greedy decode, and the
//! REINFORCE train step, per controller configuration. These two calls per
//! epoch dominate end-to-end training time, so this bench is the L2-side
//! perf ledger (EXPERIMENTS.md §Perf).

use autogmap::agent::params;
use autogmap::runtime::{literal, Runtime};
use autogmap::util::bench::Bencher;

fn main() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP rollout bench: {e}");
            return;
        }
    };
    let manifest = match rt.manifest() {
        Ok(m) => m,
        Err(e) => {
            println!("SKIP rollout bench: {e} (run `make artifacts`)");
            return;
        }
    };
    let mut b = Bencher::new();
    for name in ["qm7_diag", "qm7_dyn4", "qm7_fill_bilstm", "qh882_dyn6", "qh1484_dyn6"] {
        let entry = manifest.config(name).unwrap().clone();
        let p = params::init_params(&entry, 1);
        let opt = params::AdamState::new(&entry);
        let (bt, t) = (entry.batch, entry.steps);

        let rollout = rt.load(entry.artifact("rollout").unwrap()).unwrap();
        let mut inputs = params::to_literals(&entry, &p).unwrap();
        inputs.push(literal::lit_u32_1d(&[1, 2]));
        b.bench(&format!("rollout/{name} (B={bt},T={t})"), || {
            rollout.run(&inputs).unwrap()
        });

        let greedy = rt.load(entry.artifact("greedy").unwrap()).unwrap();
        let ginputs = params::to_literals(&entry, &p).unwrap();
        b.bench(&format!("greedy/{name}"), || greedy.run(&ginputs).unwrap());

        let train = rt.load(entry.artifact("train").unwrap()).unwrap();
        let d = vec![0i32; bt * t];
        let f = vec![0i32; bt * t];
        let adv = vec![0.5f32; bt];
        let mut tin = params::to_literals(&entry, &p).unwrap();
        tin.extend(params::to_literals(&entry, &opt.m).unwrap());
        tin.extend(params::to_literals(&entry, &opt.v).unwrap());
        tin.push(literal::lit_scalar_i32(0));
        tin.push(literal::lit_i32_2d(&d, bt, t).unwrap());
        tin.push(literal::lit_i32_2d(&f, bt, t).unwrap());
        tin.push(literal::lit_f32_1d(&adv));
        tin.push(literal::lit_scalar_f32(0.01));
        tin.push(literal::lit_scalar_f32(0.0));
        b.bench(&format!("train_step/{name}"), || train.run(&tin).unwrap());
    }

    // blocked-MVM artifact (the L1 Pallas kernel through PJRT)
    for name in ["mvm_qm7", "mvm_qh882"] {
        let mv = manifest.mvm_entry(name).unwrap();
        let exe = rt.load(&mv.artifact).unwrap();
        let tiles = vec![0.5f32; mv.nb * mv.k * mv.k];
        let x = vec![1.0f32; mv.nb * mv.k];
        let onehot = {
            let mut oh = vec![0.0f32; mv.nb * mv.nr];
            for i in 0..mv.nb {
                oh[i * mv.nr + (i % mv.nr)] = 1.0;
            }
            oh
        };
        let inputs = [
            literal::lit_f32(&tiles, &[mv.nb as i64, mv.k as i64, mv.k as i64]).unwrap(),
            literal::lit_f32(&x, &[mv.nb as i64, mv.k as i64]).unwrap(),
            literal::lit_f32(&onehot, &[mv.nb as i64, mv.nr as i64]).unwrap(),
        ];
        b.bench(
            &format!("block_mvm/{name} (NB={},K={})", mv.nb, mv.k),
            || exe.run(&inputs).unwrap(),
        );
    }
}
