//! Experiment configuration: one JSON document fully describes a run
//! (dataset, grid, reordering, controller artifact, fill geometry, reward
//! weights, optimizer hyper-parameters). The `reproduce` drivers build
//! these programmatically; users can also write them by hand and pass
//! `--config file.json`.

use crate::reorder::Reordering;
use crate::scheme::{FillRule, RewardWeights};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Which matrix to run on.
#[derive(Clone, Debug, PartialEq)]
pub enum Dataset {
    /// synthetic QM7-5828-like 22×22 molecule (seed)
    Qm7 { seed: u64 },
    /// synthetic qh882-like 882×882 (seed)
    Qh882 { seed: u64 },
    /// synthetic qh1484-like 1484×1484 (seed)
    Qh1484 { seed: u64 },
    /// batch supermatrix of `count` QM7-like graphs
    Batch { count: usize, seed: u64 },
    /// a MatrixMarket file on disk
    Mtx { path: String },
}

impl Dataset {
    pub fn parse(kind: &str, seed: u64, path: Option<&str>) -> Result<Dataset> {
        Ok(match kind {
            "qm7" => Dataset::Qm7 { seed },
            "qh882" => Dataset::Qh882 { seed },
            "qh1484" => Dataset::Qh1484 { seed },
            "batch" => Dataset::Batch { count: 4, seed },
            "mtx" => Dataset::Mtx {
                path: path.context("dataset kind `mtx` needs a path")?.to_string(),
            },
            other => bail!("unknown dataset {other:?} (qm7|qh882|qh1484|batch|mtx)"),
        })
    }

    pub fn label(&self) -> String {
        match self {
            Dataset::Qm7 { .. } => "qm7".into(),
            Dataset::Qh882 { .. } => "qh882".into(),
            Dataset::Qh1484 { .. } => "qh1484".into(),
            Dataset::Batch { count, .. } => format!("batch{count}"),
            Dataset::Mtx { path } => Path::new(path)
                .file_stem()
                .map(|s| s.to_string_lossy().to_string())
                .unwrap_or_else(|| "mtx".into()),
        }
    }
}

/// A full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub dataset: Dataset,
    /// grid cell size in matrix units
    pub grid: usize,
    pub reordering: Reordering,
    /// controller config name in the AOT manifest
    pub controller: String,
    pub fill_rule: FillRule,
    /// reward weight a (Eq. 21)
    pub reward_a: f64,
    pub lr: f32,
    pub ent_coef: f32,
    pub baseline_decay: f64,
    pub epochs: usize,
    pub seed: u64,
    /// log metrics every N epochs (0 = only at the end)
    pub log_every: usize,
}

impl ExperimentConfig {
    pub fn weights(&self) -> RewardWeights {
        RewardWeights::new(self.reward_a)
    }

    pub fn to_json(&self) -> Json {
        use crate::util::json::obj;
        let (ds_kind, ds_seed, ds_path, ds_count) = match &self.dataset {
            Dataset::Qm7 { seed } => ("qm7", *seed, None, 0),
            Dataset::Qh882 { seed } => ("qh882", *seed, None, 0),
            Dataset::Qh1484 { seed } => ("qh1484", *seed, None, 0),
            Dataset::Batch { count, seed } => ("batch", *seed, None, *count),
            Dataset::Mtx { path } => ("mtx", 0, Some(path.clone()), 0),
        };
        let (fill_kind, fill_arg) = match self.fill_rule {
            FillRule::None => ("none", 0usize),
            FillRule::Fixed { size } => ("fixed", size),
            FillRule::Dynamic { grades } => ("dynamic", grades),
        };
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("dataset", Json::Str(ds_kind.into())),
            ("dataset_seed", Json::Num(ds_seed as f64)),
            ("grid", Json::Num(self.grid as f64)),
            (
                "reorder",
                Json::Str(
                    match self.reordering {
                        Reordering::Identity => "identity",
                        Reordering::CuthillMckee => "cm",
                        Reordering::ReverseCuthillMckee => "rcm",
                    }
                    .into(),
                ),
            ),
            ("controller", Json::Str(self.controller.clone())),
            ("fill", Json::Str(fill_kind.into())),
            ("fill_arg", Json::Num(fill_arg as f64)),
            ("reward_a", Json::Num(self.reward_a)),
            ("lr", Json::Num(self.lr as f64)),
            ("ent_coef", Json::Num(self.ent_coef as f64)),
            ("baseline_decay", Json::Num(self.baseline_decay)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("log_every", Json::Num(self.log_every as f64)),
        ];
        if let Some(p) = ds_path {
            fields.push(("dataset_path", Json::Str(p)));
        }
        if ds_count > 0 {
            fields.push(("dataset_count", Json::Num(ds_count as f64)));
        }
        obj(fields)
    }

    pub fn from_json(doc: &Json) -> Result<ExperimentConfig> {
        let name = doc
            .get("name")
            .as_str()
            .context("config missing `name`")?
            .to_string();
        let ds_kind = doc.get("dataset").as_str().context("config missing `dataset`")?;
        let ds_seed = doc.get("dataset_seed").as_i64().unwrap_or(0) as u64;
        let mut dataset = Dataset::parse(ds_kind, ds_seed, doc.get("dataset_path").as_str())?;
        if let Dataset::Batch { ref mut count, .. } = dataset {
            if let Some(c) = doc.get("dataset_count").as_usize() {
                *count = c;
            }
        }
        let fill_kind = doc.get("fill").as_str().unwrap_or("none");
        let fill_arg = doc.get("fill_arg").as_usize().unwrap_or(0);
        let fill_rule = match fill_kind {
            "none" => FillRule::None,
            "fixed" => FillRule::Fixed { size: fill_arg.max(1) },
            "dynamic" => FillRule::Dynamic { grades: fill_arg.max(2) },
            other => bail!("unknown fill kind {other:?}"),
        };
        let reward_a = doc.get("reward_a").as_f64().unwrap_or(0.8);
        if !(0.0..=1.0).contains(&reward_a) {
            bail!("reward_a must be in [0,1], got {reward_a}");
        }
        Ok(ExperimentConfig {
            name,
            dataset,
            grid: doc.get("grid").as_usize().context("config missing `grid`")?,
            reordering: Reordering::parse(doc.get("reorder").as_str().unwrap_or("cm"))
                .map_err(|e| anyhow::anyhow!(e))?,
            controller: doc
                .get("controller")
                .as_str()
                .context("config missing `controller`")?
                .to_string(),
            fill_rule,
            reward_a,
            lr: doc.get("lr").as_f64().unwrap_or(0.01) as f32,
            ent_coef: doc.get("ent_coef").as_f64().unwrap_or(0.0) as f32,
            baseline_decay: doc.get("baseline_decay").as_f64().unwrap_or(0.95),
            epochs: doc.get("epochs").as_usize().unwrap_or(2000),
            seed: doc.get("seed").as_i64().unwrap_or(0) as u64,
            log_every: doc.get("log_every").as_usize().unwrap_or(50),
        })
    }

    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("config {}: {e}", path.display()))?;
        Self::from_json(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentConfig {
        ExperimentConfig {
            name: "qm7_dyn4_a80".into(),
            dataset: Dataset::Qm7 { seed: 5828 },
            grid: 2,
            reordering: Reordering::CuthillMckee,
            controller: "qm7_dyn4".into(),
            fill_rule: FillRule::Dynamic { grades: 4 },
            reward_a: 0.8,
            lr: 0.01,
            ent_coef: 0.0,
            baseline_decay: 0.95,
            epochs: 3000,
            seed: 1,
            log_every: 100,
        }
    }

    #[test]
    fn json_roundtrip() {
        let cfg = sample();
        let doc = cfg.to_json();
        let back = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.dataset, cfg.dataset);
        assert_eq!(back.grid, cfg.grid);
        assert_eq!(back.reordering, cfg.reordering);
        assert_eq!(back.fill_rule, cfg.fill_rule);
        assert_eq!(back.epochs, cfg.epochs);
        assert_eq!(back.reward_a, cfg.reward_a);
    }

    #[test]
    fn rejects_bad_fields() {
        let mut doc = sample().to_json();
        if let Json::Obj(ref mut m) = doc {
            m.insert("reward_a".into(), Json::Num(1.5));
        }
        assert!(ExperimentConfig::from_json(&doc).is_err());
        assert!(ExperimentConfig::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(Dataset::parse("bogus", 0, None).is_err());
        assert!(Dataset::parse("mtx", 0, None).is_err());
    }

    #[test]
    fn dataset_labels() {
        assert_eq!(Dataset::Qm7 { seed: 1 }.label(), "qm7");
        assert_eq!(Dataset::Batch { count: 4, seed: 1 }.label(), "batch4");
        assert_eq!(
            Dataset::Mtx { path: "/x/y/qh882.mtx".into() }.label(),
            "qh882"
        );
    }
}
