//! Sparse graph substrate: matrix types, MatrixMarket IO, grid coarsening,
//! and synthetic dataset generators.

pub mod grid;
pub mod matrix_market;
pub mod sparse;
pub mod storage;
pub mod synth;

pub use grid::GridSummary;
pub use sparse::{Coo, Csr};
