//! Hierarchical sparsity-aware mapping for graphs far beyond the
//! controller's native grid — the subsystem that turns the paper's
//! qh1484-scale method into a 100k-node pipeline.
//!
//! The paper's controller decides one flat rollout over an N-cell grid
//! (N ≤ 47). GraphR-style ReRAM graph processing instead streams many
//! small sub-blocks through fixed crossbar resources; this module is that
//! scalability layer. The end-to-end flow in front of the engine's
//! plan → fleet → batch pipeline ([`crate::engine`]):
//!
//! 1. **window** ([`window`]) — after RCM reordering concentrates nnz in a
//!    band, slice the grid diagonal into overlapping controller-sized
//!    windows and choose min-crossing ownership cuts between neighbours;
//! 2. **infer** ([`infer`]) — per *unique* window occupancy signature
//!    ([`cache`]), run trained-controller inference on the native backend
//!    (sampled rollouts + greedy decode, with the DP oracle and the full
//!    window block as completeness safety nets) in parallel on the shared
//!    [`crate::util::pool::WorkerPool`]; repeated sparsity patterns are
//!    mapped once — at 0.99+ sparsity most windows collide, so the cache
//!    hit rate is the pipeline's amortization lever;
//! 3. **stitch** ([`crate::scheme::CompositeScheme`]) — clip each window's
//!    scheme to its owned diagonal square; the composite preserves the
//!    paper's no-overlap/coverage principles globally, with off-window
//!    band nnz accounted as digital spill ([`crate::graph::storage`]);
//! 4. **execute** ([`exec`]) — compile each window to an
//!    [`crate::engine::ExecPlan`], merge them
//!    ([`crate::engine::merge_plans`]) into one schedule a
//!    [`crate::engine::Fleet`] shards across banks, and serve exact
//!    y = Ax (mapped tiles + spill): [`CompositePlan`] implements
//!    [`crate::engine::Servable`], so the generic
//!    [`crate::engine::BatchExecutor`] — and the `crate::api::Deployment`
//!    facade above it — serve composites and flat plans identically.
//!
//! The `map-large` CLI subcommand drives the whole pipeline on a
//! deterministic R-MAT graph ([`crate::graph::synth::rmat_like`]) and
//! emits `BENCH_mapper.json` (mapped nnz/s at 1/2/8 workers, global area
//! ratio vs. the fixed-block baseline, cache hit rate).
//!
//! Mapping is bit-deterministic: window positions, cuts, and signatures
//! are computed before any job is dispatched, inference is a pure function
//! of (params, signature, seed), and slices assemble in window order — so
//! the composite is identical for any worker count.

pub mod cache;
pub mod exec;
pub mod infer;
pub mod window;

pub use exec::{compile_composite, CompositePlan};
pub use infer::InferContext;

use crate::graph::GridSummary;
use crate::scheme::{CompositeScheme, WindowSlice};
use crate::util::pool::WorkerPool;
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::Instant;

/// Mapper configuration: the per-window inference context (controller,
/// params, fill rule, reward weights, sampling rounds, seed) plus the
/// mapper's own windowing/parallelism knobs.
pub struct MapperConfig {
    pub infer: InferContext,
    /// window overlap in grid cells (cut search space between neighbours)
    pub overlap: usize,
    /// inference worker threads (results are identical for any value)
    pub workers: usize,
}

/// Mapping run statistics.
#[derive(Clone, Copy, Debug)]
pub struct MapReport {
    pub windows: usize,
    /// distinct window signatures this run touched
    pub unique_windows: usize,
    /// total entries in the scheme cache after the run (== `unique_windows`
    /// for a fresh cache; grows monotonically for a persistent one)
    pub cache_entries: usize,
    /// windows of this run answered from the cache without inference
    pub cache_hits: usize,
    /// fraction of this run's windows answered from the cache
    pub cache_hit_rate: f64,
    pub wall_seconds: f64,
}

/// Map a (reordered) matrix end-to-end into a validated composite scheme.
///
/// `g` must summarize the matrix the composite will later compile against
/// (the mapper itself never touches the matrix — everything it needs is in
/// the grid summary).
pub fn map_graph(g: &GridSummary, cfg: &MapperConfig) -> Result<(CompositeScheme, MapReport)> {
    let mut cache = cache::SchemeCache::new();
    map_graph_with_cache(g, cfg, &mut cache)
}

/// [`map_graph`] against a caller-owned [`cache::SchemeCache`] that
/// survives across calls — the incremental-remap lever: windows whose
/// occupancy signature is already interned (from a previous mapping of a
/// mostly-unchanged matrix) are cache hits by construction and skip
/// inference entirely. The report's `cache_hit_rate` counts only *this*
/// run's windows, so a warm cache shows up as a high per-run hit rate.
pub fn map_graph_with_cache(
    g: &GridSummary,
    cfg: &MapperConfig,
    cache: &mut cache::SchemeCache,
) -> Result<(CompositeScheme, MapReport)> {
    crate::agent::validate_fill_rule(&cfg.infer.entry, &cfg.infer.fill_rule)?;
    ensure!(cfg.infer.entry.n >= 2, "controller needs at least 2 grid cells");
    let t0 = Instant::now();

    // 1. windows + ownership cuts (content-aware, scheme-independent)
    let spans = window::plan_windows(g.n, cfg.infer.entry.n, cfg.overlap);
    let cuts = window::choose_cuts(g, &spans);

    // 2. signatures, interned: inference runs once per unique pattern
    let mut locals = Vec::with_capacity(spans.len());
    let mut entry_ids = Vec::with_capacity(spans.len());
    let mut sig_hashes = Vec::with_capacity(spans.len());
    let mut hits = Vec::with_capacity(spans.len());
    for s in &spans {
        let local = g.window(s.start, s.len());
        let sig = cache::signature(&local);
        sig_hashes.push(sig.hash);
        let (id, hit) = cache.intern(sig);
        locals.push(local);
        entry_ids.push(id);
        hits.push(hit);
    }

    // 3. parallel inference over the missed entries only
    let ctx = Arc::new(cfg.infer.clone());
    let misses = cache.unfilled();
    let jobs: Vec<_> = misses
        .iter()
        .map(|&id| {
            // first window interning this entry supplies the local summary
            let w = entry_ids.iter().position(|&e| e == id).expect("entry has a window");
            let local = locals[w].clone();
            let hash = sig_hashes[w];
            let ctx = ctx.clone();
            move || infer::map_window(&ctx, &local, hash)
        })
        .collect();
    let pool = WorkerPool::new(cfg.workers.max(1));
    let schemes = pool.run(jobs);
    for (&id, scheme) in misses.iter().zip(schemes) {
        cache.fill(id, scheme);
    }

    // 4. stitch: owned ranges from the cuts, schemes from the cache
    let slices: Vec<WindowSlice> = spans
        .iter()
        .enumerate()
        .map(|(i, s)| WindowSlice {
            win_start: s.start,
            win_end: s.end,
            start: if i == 0 { 0 } else { cuts[i - 1] },
            end: if i + 1 == spans.len() { g.n } else { cuts[i] },
            scheme: cache.scheme(entry_ids[i]).clone(),
            cache_hit: hits[i],
        })
        .collect();
    let comp = CompositeScheme { n: g.n, slices };
    comp.validate(g.n)
        .map_err(|e| anyhow::anyhow!("mapper produced an invalid composite: {e}"))?;
    let mut distinct = entry_ids.clone();
    distinct.sort_unstable();
    distinct.dedup();
    let run_hits = hits.iter().filter(|h| **h).count();
    Ok((
        comp,
        MapReport {
            windows: spans.len(),
            unique_windows: distinct.len(),
            cache_entries: cache.unique(),
            cache_hits: run_hits,
            cache_hit_rate: if spans.is_empty() {
                0.0
            } else {
                run_hits as f64 / spans.len() as f64
            },
            wall_seconds: t0.elapsed().as_secs_f64(),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::params::init_params;
    use crate::graph::synth;
    use crate::reorder::{reorder, Reordering};
    use crate::runtime::manifest::ControllerEntry;
    use crate::scheme::{FillRule, RewardWeights};

    fn small_cfg(n: usize, workers: usize) -> MapperConfig {
        let entry = ControllerEntry::from_dims("mapper_test", n, 5, 4, 4, false);
        let params = init_params(&entry, 17);
        MapperConfig {
            infer: InferContext {
                entry,
                params,
                fill_rule: FillRule::Dynamic { grades: 4 },
                weights: RewardWeights::new(0.8),
                rounds: 2,
                seed: 5,
            },
            overlap: 2,
            workers,
        }
    }

    #[test]
    fn maps_banded_matrix_completely_with_cache_reuse() {
        let m = synth::banded_like(400, 0.98, 3);
        let r = reorder(&m, Reordering::ReverseCuthillMckee);
        let g = GridSummary::new(&r.matrix, 8); // n = 50
        let cfg = small_cfg(8, 2);
        let (comp, report) = map_graph(&g, &cfg).unwrap();
        comp.validate(g.n).unwrap();
        assert_eq!(report.windows, comp.slices.len());
        assert!(report.unique_windows <= report.windows);
        let e = comp.evaluate(&g, 4);
        // window-complete schemes -> all windowed nnz covered
        assert_eq!(e.coverage_windowed, 1.0);
        assert_eq!(e.covered_nnz + e.spilled_nnz, e.total_nnz);
        // least-area bound: never worse than one fixed block per owned range
        let bound: u64 = comp
            .slices
            .iter()
            .map(|s| g.rect_area(s.start, s.end, s.start, s.end))
            .sum();
        assert!(e.covered_area_units <= bound);
    }

    #[test]
    fn mapping_is_identical_across_worker_counts() {
        let m = synth::banded_like(300, 0.97, 9);
        let r = reorder(&m, Reordering::ReverseCuthillMckee);
        let g = GridSummary::new(&r.matrix, 6); // n = 50
        let a = map_graph(&g, &small_cfg(7, 1)).unwrap().0;
        let b = map_graph(&g, &small_cfg(7, 2)).unwrap().0;
        let c = map_graph(&g, &small_cfg(7, 8)).unwrap().0;
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn repeated_patterns_hit_the_cache() {
        // a long pure-diagonal matrix: every interior window shares one
        // signature, so the hit rate is high
        let mut coo = crate::graph::Coo::new(600, 600);
        for i in 0..600 {
            coo.push(i, i, 1.0);
        }
        let m = coo.to_csr();
        let g = GridSummary::new(&m, 4); // n = 150
        let cfg = small_cfg(10, 2);
        let (comp, report) = map_graph(&g, &cfg).unwrap();
        assert!(report.windows > 10);
        assert!(
            report.cache_hit_rate > 0.5,
            "diagonal windows should collide: hit rate {}",
            report.cache_hit_rate
        );
        assert_eq!(comp.evaluate(&g, 4).coverage_windowed, 1.0);
    }

    #[test]
    fn whole_graph_smaller_than_one_window_still_maps() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2); // n = 11 < controller n = 16
        let cfg = small_cfg(16, 1);
        let (comp, report) = map_graph(&g, &cfg).unwrap();
        assert_eq!(report.windows, 1);
        assert_eq!(comp.slices.len(), 1);
        let e = comp.evaluate(&g, 4);
        assert_eq!(e.coverage_windowed, 1.0);
        assert_eq!(e.spilled_nnz, 0, "single window spills nothing");
    }

    #[test]
    fn fill_rule_mismatch_is_rejected() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2);
        let mut cfg = small_cfg(8, 1);
        cfg.infer.fill_rule = FillRule::None;
        assert!(map_graph(&g, &cfg).is_err());
    }
}
