//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so we implement PCG64 (O'Neill,
//! "PCG: A Family of Simple Fast Space-Efficient Statistically Good
//! Algorithms for Random Number Generation") plus the distribution helpers
//! the rest of the crate needs. All experiment entropy flows through this
//! type so every run is reproducible from a single `u64` seed.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed the generator. `seed` selects the starting state, `stream`
    /// selects one of 2^127 distinct sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 1) | 1) ^ 0x5851_f42d_4c95_7f2d;
        let mut rng = Pcg64 {
            state: 0,
            inc,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seed_from_u64(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Next 32 random bits.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire's method).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Panics if all weights are zero or any is negative.
    pub fn multinomial(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && weights.iter().all(|w| *w >= 0.0),
            "multinomial needs non-negative weights with positive sum"
        );
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Split off an independent generator (distinct stream derived from
    /// the current state) — used to hand deterministic sub-seeds to
    /// parallel workers.
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::new(seed, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg64::seed_from_u64(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.below(10) as usize] += 1;
        }
        for c in counts {
            // expected 10_000, allow ±5%
            assert!((9_500..10_500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn below_one_is_zero() {
        let mut rng = Pcg64::seed_from_u64(3);
        for _ in 0..32 {
            assert_eq!(rng.below(1), 0);
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from_u64(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn multinomial_respects_weights() {
        let mut rng = Pcg64::seed_from_u64(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.multinomial(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.7..3.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::seed_from_u64(1234);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
