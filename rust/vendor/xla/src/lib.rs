//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The real crate links the PJRT CPU runtime and compiles HLO-text
//! artifacts; that shared library is not available in the offline vendored
//! build, so this stub keeps the crate compiling and everything that does
//! not touch the device working:
//!
//! - [`Literal`] is a fully functional host-side tensor container
//!   (construction, reshape, typed extraction) — parameter stores,
//!   checkpoints, and their tests behave exactly as with the real crate;
//! - [`PjRtClient::cpu`] succeeds (so `Runtime::new` and manifest loading
//!   work), but [`HloModuleProto::from_text_file`] and
//!   [`PjRtClient::compile`] return descriptive errors: any path that
//!   actually needs to execute an AOT artifact fails loudly with the
//!   reason, instead of crashing at link time.
//!
//! Swapping the real `xla` crate back in is a one-line change in the root
//! `Cargo.toml`; no call sites reference stub-only API.

use std::borrow::Borrow;
use std::fmt;

const UNAVAILABLE: &str = "PJRT runtime unavailable: this build vendors the offline `xla` stub \
     (xla_extension is not installed), so HLO artifacts cannot be compiled or executed";

/// Stub error type (mirrors `xla::Error` closely enough for `?` + context).
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element buffer of a [`Literal`]. Public only so [`NativeType`] can name
/// it in its associated functions; not part of the stable surface.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

impl Buf {
    fn dtype(&self) -> &'static str {
        match self {
            Buf::F32(_) => "f32",
            Buf::I32(_) => "i32",
            Buf::U32(_) => "u32",
            Buf::Tuple(_) => "tuple",
        }
    }
}

/// Element types a [`Literal`] can hold (the subset this repo uses).
pub trait NativeType: Copy {
    const DTYPE: &'static str;
    #[doc(hidden)]
    fn buf_from(data: Vec<Self>) -> Buf;
    #[doc(hidden)]
    fn extract(buf: &Buf) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $variant:ident, $name:literal) => {
        impl NativeType for $t {
            const DTYPE: &'static str = $name;
            fn buf_from(data: Vec<Self>) -> Buf {
                Buf::$variant(data)
            }
            fn extract(buf: &Buf) -> Option<Vec<Self>> {
                match buf {
                    Buf::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32, "f32");
native!(i32, I32, "i32");
native!(u32, U32, "u32");

/// Host-side tensor: typed element buffer plus a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            buf: T::buf_from(data.to_vec()),
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: Vec::new(),
            buf: T::buf_from(vec![v]),
        }
    }

    /// Tuple literal (what executables return).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![elements.len() as i64],
            buf: Buf::Tuple(elements),
        }
    }

    /// Total element count (tuple arity for tuples).
    pub fn element_count(&self) -> usize {
        match &self.buf {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::U32(v) => v.len(),
            Buf::Tuple(t) => t.len(),
        }
    }

    /// Same data, new shape; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        if expect < 0 || expect as usize != self.element_count() {
            return Err(Error::new(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            buf: self.buf.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Extract the elements as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.buf).ok_or_else(|| {
            Error::new(format!(
                "literal holds {}, requested {}",
                self.buf.dtype(),
                T::DTYPE
            ))
        })
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.buf {
            Buf::Tuple(t) => Ok(t.clone()),
            other => Err(Error::new(format!(
                "literal holds {}, not a tuple",
                other.dtype()
            ))),
        }
    }
}

/// PJRT client stub: constructible (the host side of `Runtime` works), but
/// compilation reports PJRT unavailable.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// HLO-text module handle; loading always fails in the stub.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::new(format!("{UNAVAILABLE} (while loading {path})")))
    }
}

/// Computation wrapper (never executable in the stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer stub.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Loaded executable stub (unreachable in practice: `compile` errors).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        assert_eq!(Literal::scalar(-7i32).to_vec::<i32>().unwrap(), vec![-7]);
        assert_eq!(Literal::scalar(5u32).element_count(), 1);
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(1.0f32).to_tuple().is_err());
    }

    #[test]
    fn client_up_compile_down() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("cpu"));
        assert_eq!(c.device_count(), 1);
        let err = HloModuleProto::from_text_file("/tmp/x.hlo.txt").unwrap_err();
        assert!(format!("{err}").contains("/tmp/x.hlo.txt"));
    }
}
