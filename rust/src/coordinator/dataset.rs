//! Dataset resolution: turn a [`Dataset`](super::config::Dataset) spec into
//! a loaded, reordered, grid-summarized matrix ready for training.

use super::config::{Dataset, ExperimentConfig};
use crate::graph::{matrix_market, synth, Csr, GridSummary};
use crate::reorder::{reorder, Reordered};
use anyhow::{Context, Result};
use std::path::Path;

/// A fully prepared workload.
pub struct Workload {
    /// the original (un-reordered) matrix
    pub original: Csr,
    /// reordering result (matrix + permutation + bandwidth stats)
    pub reordered: Reordered,
    /// grid summary of the *reordered* matrix
    pub grid: GridSummary,
}

/// Materialize the matrix for a dataset spec.
pub fn load_matrix(ds: &Dataset) -> Result<Csr> {
    Ok(match ds {
        Dataset::Qm7 { seed } => synth::qm7_like(*seed),
        Dataset::Qh882 { seed } => synth::qh882_like(*seed),
        Dataset::Qh1484 { seed } => synth::qh1484_like(*seed),
        Dataset::Batch { count, seed } => {
            let graphs: Vec<Csr> = (0..*count)
                .map(|i| synth::qm7_like(seed.wrapping_add(i as u64)))
                .collect();
            synth::batch_supermatrix(&graphs)
        }
        Dataset::Mtx { path } => matrix_market::read(Path::new(path))
            .with_context(|| format!("loading MatrixMarket file {path}"))?,
    })
}

/// Load + reorder + grid-summarize per the experiment config.
pub fn prepare(cfg: &ExperimentConfig) -> Result<Workload> {
    let original = load_matrix(&cfg.dataset)?;
    let reordered = reorder(&original, cfg.reordering);
    let grid = GridSummary::new(&reordered.matrix, cfg.grid);
    Ok(Workload {
        original,
        reordered,
        grid,
    })
}

/// Write the three paper datasets to `dir` as .mtx files (the `gen-data`
/// CLI command), so runs are reproducible from on-disk artifacts too.
pub fn generate_all(dir: &Path) -> Result<Vec<(String, usize, usize)>> {
    std::fs::create_dir_all(dir)?;
    let sets: Vec<(&str, Csr)> = vec![
        ("qm7_5828", synth::qm7_like(5828)),
        ("qh882", synth::qh882_like(882)),
        ("qh1484", synth::qh1484_like(1484)),
    ];
    let mut out = Vec::new();
    for (name, m) in sets {
        let path = dir.join(format!("{name}.mtx"));
        matrix_market::write(&path, &m)?;
        out.push((name.to_string(), m.rows, m.nnz()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reorder::Reordering;
    use crate::scheme::FillRule;

    #[test]
    fn prepare_qm7_shapes() {
        let cfg = ExperimentConfig {
            name: "t".into(),
            dataset: Dataset::Qm7 { seed: 5828 },
            grid: 2,
            reordering: Reordering::CuthillMckee,
            controller: "qm7_dyn4".into(),
            fill_rule: FillRule::Dynamic { grades: 4 },
            reward_a: 0.8,
            lr: 0.01,
            ent_coef: 0.0,
            baseline_decay: 0.95,
            epochs: 10,
            seed: 0,
            log_every: 0,
        };
        let w = prepare(&cfg).unwrap();
        assert_eq!(w.grid.n, 11);
        assert_eq!(w.original.nnz(), w.reordered.matrix.nnz());
        assert!(w.reordered.bandwidth_after <= w.reordered.bandwidth_before);
    }

    #[test]
    fn gen_data_roundtrip() {
        let dir = std::env::temp_dir().join("autogmap_gen_data_test");
        let stats = generate_all(&dir).unwrap();
        assert_eq!(stats.len(), 3);
        let m = load_matrix(&Dataset::Mtx {
            path: dir.join("qm7_5828.mtx").to_string_lossy().into_owned(),
        })
        .unwrap();
        assert_eq!(m.rows, 22);
        assert_eq!(m, synth::qm7_like(5828));
    }

    #[test]
    fn batch_dataset_composes() {
        let m = load_matrix(&Dataset::Batch { count: 3, seed: 9 }).unwrap();
        assert_eq!(m.rows, 66);
    }
}
