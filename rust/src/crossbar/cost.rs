//! Peripheral-circuit and energy cost model.
//!
//! The paper's motivation for minimizing mapped area and for keeping
//! same-row blocks connected is peripheral cost: every mapped cell costs
//! memristors and write energy; every block row needs ADC + accumulation
//! wiring; every block column needs DAC drive; and scattered blocks
//! increase "the complexity of peripheral circuits and communication
//! between sub-crossbars". This model turns a placed [`CrossbarArray`]
//! into those counts with standard per-component constants (ISAAC/PRIME-
//! class numbers; the absolute values matter less than the ordering of
//! schemes, which is what the benches compare).

use super::CrossbarArray;

/// Per-component cost constants. Defaults follow ISAAC-era estimates:
/// 1T1R cell read ~ 1 pJ/op at 1.2V, 8-bit SAR ADC ~ 2 pJ/sample,
/// DAC ~ 0.5 pJ/sample, switch crossover ~ 0.1 pJ.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub cell_read_pj: f64,
    pub adc_sample_pj: f64,
    pub dac_sample_pj: f64,
    pub switch_pj: f64,
    /// crossbar read latency per tile (analog settle + ADC), ns
    pub tile_read_ns: f64,
    /// tiles that can be read concurrently (array-level parallelism)
    pub parallel_tiles: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            cell_read_pj: 1.0,
            adc_sample_pj: 2.0,
            dac_sample_pj: 0.5,
            switch_pj: 0.1,
            tile_read_ns: 100.0,
            parallel_tiles: 64,
        }
    }
}

/// Cost estimate for one MVM pass over a placed array.
#[derive(Clone, Debug, PartialEq)]
pub struct CostEstimate {
    pub tiles: usize,
    /// programmed memristor cells inside the matrix (clipped at the edge —
    /// the paper's Area metric; edge-truncated tiles count their rows×cols
    /// actually used, not the padded K²)
    pub cells: u64,
    /// ADC conversions: one per in-matrix row wire per tile
    pub adc_samples: u64,
    /// DAC drives: one per in-matrix column wire per tile
    pub dac_samples: u64,
    pub energy_pj: f64,
    pub latency_ns: f64,
    /// distinct block-row segments (accumulation wire count)
    pub row_segments: usize,
}

impl CostModel {
    /// Estimate from raw component counts — the shared primitive behind
    /// [`Self::estimate`] and the engine fleet's per-bank accounting
    /// (`crate::engine::fleet::Fleet::bank_estimates`).
    pub fn estimate_counts(
        &self,
        tiles: usize,
        cells: u64,
        adc_samples: u64,
        dac_samples: u64,
        switch_crossovers: u64,
        row_segments: usize,
    ) -> CostEstimate {
        let energy_pj = cells as f64 * self.cell_read_pj
            + adc_samples as f64 * self.adc_sample_pj
            + dac_samples as f64 * self.dac_sample_pj
            + switch_crossovers as f64 * self.switch_pj * 2.0; // in + out
        let waves = tiles.div_ceil(self.parallel_tiles.max(1));
        let latency_ns = if tiles == 0 {
            0.0
        } else {
            waves as f64 * self.tile_read_ns
        };
        CostEstimate {
            tiles,
            cells,
            adc_samples,
            dac_samples,
            energy_pj,
            latency_ns,
            row_segments,
        }
    }

    /// Estimate one y' = A'x' pass. `switch_crossovers` comes from
    /// [`super::switch::SwitchCircuit::crossover_count`] (0 when no
    /// reordering is applied). Cell/ADC/DAC counts use clipped tile
    /// extents: the zero-padded overhang of edge-truncated tiles draws no
    /// read current and needs no conversions.
    pub fn estimate(&self, arr: &CrossbarArray, switch_crossovers: u64) -> CostEstimate {
        let mut adc_samples = 0u64;
        let mut dac_samples = 0u64;
        for t in &arr.tiles {
            let (r, c) = arr.clipped_extents(t);
            adc_samples += r as u64;
            dac_samples += c as u64;
        }
        self.estimate_counts(
            arr.tiles.len(),
            arr.area_cells_clipped(),
            adc_samples,
            dac_samples,
            switch_crossovers,
            arr.row_segments(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::place;
    use crate::graph::{synth, GridSummary};
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::{parse_actions, FillRule, Scheme};

    fn placed(diag_only: bool) -> CrossbarArray {
        let m = synth::qm7_like(5828);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 2);
        let s = if diag_only {
            parse_actions(g.n, &[0; 10], &[0; 10], FillRule::None)
        } else {
            Scheme { diag_len: vec![g.n], fill_len: vec![] }
        };
        place(&r.matrix, &g, &s).unwrap()
    }

    #[test]
    fn smaller_schemes_cost_less() {
        let model = CostModel::default();
        let unit = model.estimate(&placed(true), 0);
        let full = model.estimate(&placed(false), 0);
        assert!(unit.cells < full.cells);
        assert!(unit.energy_pj < full.energy_pj);
        assert!(unit.tiles < full.tiles);
    }

    #[test]
    fn counts_are_consistent() {
        let model = CostModel::default();
        let arr = placed(false);
        let est = model.estimate(&arr, 0);
        assert_eq!(est.tiles, arr.tiles.len());
        assert_eq!(est.cells, arr.area_cells());
        assert_eq!(est.adc_samples, (arr.tiles.len() * arr.k) as u64);
        assert!(est.latency_ns > 0.0);
    }

    #[test]
    fn truncated_tiles_cost_their_clipped_extents() {
        // qh882 at grid 32: 882 = 27*32 + 18, so edge tiles must charge
        // for 18-unit strips, not full 32s.
        let m = synth::qh882_like(1);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 32);
        let s = Scheme { diag_len: vec![g.n], fill_len: vec![] };
        let arr = place(&r.matrix, &g, &s).unwrap();
        let est = CostModel::default().estimate(&arr, 0);
        assert_eq!(est.cells, arr.area_cells_clipped());
        assert_eq!(est.cells, 882 * 882);
        assert!(est.cells < arr.area_cells());
        // 28 tiles per row: 27 full (32 rows) + 1 truncated (18 rows)
        assert_eq!(est.adc_samples, 28 * (27 * 32 + 18));
        assert_eq!(est.dac_samples, est.adc_samples);
    }

    #[test]
    fn estimate_counts_is_the_shared_primitive() {
        let model = CostModel::default();
        let arr = placed(false);
        let est = model.estimate(&arr, 0);
        let direct = model.estimate_counts(
            est.tiles,
            est.cells,
            est.adc_samples,
            est.dac_samples,
            0,
            est.row_segments,
        );
        assert_eq!(est, direct);
        let empty = model.estimate_counts(0, 0, 0, 0, 0, 0);
        assert_eq!(empty.latency_ns, 0.0);
        assert_eq!(empty.energy_pj, 0.0);
    }

    #[test]
    fn switch_crossovers_add_energy() {
        let model = CostModel::default();
        let arr = placed(true);
        let a = model.estimate(&arr, 0);
        let b = model.estimate(&arr, 1000);
        assert!(b.energy_pj > a.energy_pj);
        assert_eq!(a.cells, b.cells);
    }

    #[test]
    fn latency_scales_with_waves() {
        let mut model = CostModel::default();
        let arr = placed(false); // 121 tiles
        model.parallel_tiles = 1;
        let serial = model.estimate(&arr, 0);
        model.parallel_tiles = 1024;
        let parallel = model.estimate(&arr, 0);
        assert!(serial.latency_ns > parallel.latency_ns);
        assert_eq!(parallel.latency_ns, model.tile_read_ns);
    }
}
