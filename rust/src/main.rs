//! `autogmap` — CLI for the AutoGMap reproduction.
//!
//! Subcommands:
//!   train      — run one RL experiment from a JSON config or flags
//!   eval       — greedy-decode a trained checkpoint and print the scheme
//!   baseline   — run the non-RL baselines on a dataset
//!   reproduce  — regenerate a paper table (--table) or figure (--figure)
//!   gen-data   — write the synthetic datasets to data/ as .mtx
//!   visualize  — spy-plot a dataset (ASCII + SVG)
//!   info       — runtime + manifest summary

use autogmap::coordinator::config::{Dataset, ExperimentConfig};
use autogmap::coordinator::{reproduce, runner, RunnerOptions};
use autogmap::reorder::Reordering;
use autogmap::runtime::Runtime;
use autogmap::scheme::FillRule;
use autogmap::util::cli::Args;
use std::path::{Path, PathBuf};

const USAGE: &str = "\
autogmap — learning to map large-scale sparse graphs on memristive crossbars

USAGE: autogmap <subcommand> [options]

  train      --config cfg.json | [--dataset qm7|qh882|qh1484|batch|mtx
             --mtx-path p --grid N --controller NAME --fill none|fixed|dynamic
             --fill-arg N --reward-a F --lr F --epochs N --seed N]
             [--out runs] [--checkpoint-every N] [--verbose]
  eval       --config cfg.json --checkpoint runs/<name>/checkpoint.json
  baseline   --dataset qm7|qh882|qh1484 [--grid N] [--coarse N]
  reproduce  --table 2|3|4 | --figure 2|7|8|9|10|11|12|13 [--epochs N] [--out runs]
  gen-data   [--out data]
  visualize  --dataset qm7|qh882|qh1484 [--mtx-path p] [--out figures]
  info

  global: --artifacts DIR (default: artifacts)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let value_opts = [
        "config", "dataset", "mtx-path", "grid", "controller", "fill", "fill-arg",
        "reward-a", "lr", "ent-coef", "epochs", "seed", "out", "checkpoint-every",
        "checkpoint", "table", "figure", "artifacts", "coarse", "reorder", "log-every",
    ];
    let flag_opts = ["verbose", "help"];
    let args = Args::parse(argv, &value_opts, &flag_opts, true)
        .map_err(|e| anyhow::anyhow!("{e}\n\n{USAGE}"))?;
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let sub = args.subcommand.clone().unwrap_or_default();
    match sub.as_str() {
        "train" => cmd_train(&args, &artifacts),
        "eval" => cmd_eval(&args, &artifacts),
        "baseline" => cmd_baseline(&args),
        "reproduce" => cmd_reproduce(&args, &artifacts),
        "gen-data" => cmd_gen_data(&args),
        "visualize" => cmd_visualize(&args),
        "info" => cmd_info(&artifacts),
        other => anyhow::bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

fn dataset_from_args(args: &Args) -> anyhow::Result<Dataset> {
    let kind = args.get_or("dataset", "qm7");
    let seed = args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or_else(|| match kind {
        "qm7" => 5828,
        "qh882" => 882,
        "qh1484" => 1484,
        _ => 0,
    });
    Dataset::parse(kind, seed, args.get("mtx-path")).map_err(|e| anyhow::anyhow!(e))
}

fn config_from_args(args: &Args) -> anyhow::Result<ExperimentConfig> {
    if let Some(path) = args.get("config") {
        let mut cfg = ExperimentConfig::load(Path::new(path))?;
        // flag overrides
        if let Some(e) = args.get_usize("epochs").map_err(anyhow::Error::msg)? {
            cfg.epochs = e;
        }
        if let Some(s) = args.get_u64("seed").map_err(anyhow::Error::msg)? {
            cfg.seed = s;
        }
        return Ok(cfg);
    }
    let dataset = dataset_from_args(args)?;
    let fill_kind = args.get_or("fill", "dynamic");
    let fill_arg = args.get_usize("fill-arg").map_err(anyhow::Error::msg)?.unwrap_or(4);
    let fill_rule = match fill_kind {
        "none" => FillRule::None,
        "fixed" => FillRule::Fixed { size: fill_arg.max(1) },
        "dynamic" => FillRule::Dynamic { grades: fill_arg.max(2) },
        other => anyhow::bail!("unknown fill {other:?}"),
    };
    let default_controller = match (&dataset, &fill_rule) {
        (Dataset::Qm7 { .. }, FillRule::None) => "qm7_diag",
        (Dataset::Qm7 { .. }, FillRule::Fixed { .. }) => "qm7_fill",
        (Dataset::Qm7 { .. }, FillRule::Dynamic { grades: 6 }) => "qm7_dyn6",
        (Dataset::Qm7 { .. }, FillRule::Dynamic { .. }) => "qm7_dyn4",
        (Dataset::Qh882 { .. }, FillRule::Dynamic { grades: 6 }) => "qh882_dyn6",
        (Dataset::Qh882 { .. }, _) => "qh882_dyn4",
        (Dataset::Qh1484 { .. }, FillRule::Dynamic { grades: 6 }) => "qh1484_dyn6",
        (Dataset::Qh1484 { .. }, _) => "qh1484_dyn4",
        _ => anyhow::bail!("pass --controller for this dataset"),
    };
    let controller = args.get_or("controller", default_controller).to_string();
    let grid_default = match &dataset {
        Dataset::Qm7 { .. } => 2,
        _ => 32,
    };
    Ok(ExperimentConfig {
        name: format!("{}_{}", controller, args.get_or("reward-a", "0.8").replace('.', "")),
        dataset,
        grid: args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(grid_default),
        reordering: Reordering::parse(args.get_or("reorder", "cm")).map_err(anyhow::Error::msg)?,
        controller,
        fill_rule,
        reward_a: args.get_f64("reward-a").map_err(anyhow::Error::msg)?.unwrap_or(0.8),
        lr: args.get_f64("lr").map_err(anyhow::Error::msg)?.unwrap_or(0.015) as f32,
        ent_coef: args.get_f64("ent-coef").map_err(anyhow::Error::msg)?.unwrap_or(0.002) as f32,
        baseline_decay: 0.95,
        epochs: args.get_usize("epochs").map_err(anyhow::Error::msg)?.unwrap_or(4000),
        seed: args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(0),
        log_every: args.get_usize("log-every").map_err(anyhow::Error::msg)?.unwrap_or(50),
    })
}

fn cmd_train(args: &Args, artifacts: &str) -> anyhow::Result<()> {
    let cfg = config_from_args(args)?;
    let rt = Runtime::new(artifacts)?;
    let opts = RunnerOptions {
        out_root: PathBuf::from(args.get_or("out", "runs")),
        checkpoint_every: args
            .get_usize("checkpoint-every")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(500),
        verbose: args.flag("verbose"),
        keep_history: true,
    };
    println!("training {} on {} for {} epochs …", cfg.controller, cfg.dataset.label(), cfg.epochs);
    let result = runner::run_experiment(&rt, &cfg, &opts)?;
    println!("{}", runner::curves_ascii(&result.history, 78, 14));
    println!("best: {}", runner::describe_best(&result.best, &result.workload.grid));
    println!(
        "wall {:.1}s  ({:.1} epochs/s)  artifacts: {}",
        result.wall_seconds,
        cfg.epochs as f64 / result.wall_seconds,
        result.run_dir.display()
    );
    Ok(())
}

fn cmd_eval(args: &Args, artifacts: &str) -> anyhow::Result<()> {
    let cfg = config_from_args(args)?;
    let rt = Runtime::new(artifacts)?;
    let manifest = rt.manifest()?;
    let entry = manifest.config(&cfg.controller)?.clone();
    let workload = autogmap::coordinator::dataset::prepare(&cfg)?;
    let topts = autogmap::agent::TrainOptions {
        lr: cfg.lr,
        ent_coef: cfg.ent_coef,
        baseline_decay: cfg.baseline_decay,
        weights: cfg.weights(),
        fill_rule: cfg.fill_rule,
        seed: cfg.seed,
    };
    let mut trainer = autogmap::agent::Trainer::new(&rt, entry, topts)?;
    if let Some(ck) = args.get("checkpoint") {
        trainer.restore(Path::new(ck))?;
        println!("restored checkpoint {ck} (epoch {})", trainer.epoch);
    }
    let (scheme, eval) = trainer.greedy(&workload.grid)?;
    println!(
        "greedy scheme: diag {:?} fill {:?}",
        scheme.diag_sizes_units(&workload.grid),
        scheme.fill_len
    );
    println!(
        "coverage {:.4}  area {:.4}  sparsity {:.4}  reward {:.4}",
        eval.coverage_ratio, eval.area_ratio, eval.sparsity, eval.reward
    );
    if workload.grid.dim <= 64 {
        println!(
            "{}",
            autogmap::viz::ascii_scheme(&workload.reordered.matrix, &workload.grid, &scheme)
        );
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> anyhow::Result<()> {
    let ds = dataset_from_args(args)?;
    let grid = args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(match ds {
        Dataset::Qm7 { .. } => 1,
        _ => 32,
    });
    let coarse = args.get_usize("coarse").map_err(anyhow::Error::msg)?.unwrap_or(8);
    reproduce::baselines_report(&ds, grid, coarse)
}

fn cmd_reproduce(args: &Args, artifacts: &str) -> anyhow::Result<()> {
    let table = args.get_usize("table").map_err(anyhow::Error::msg)?;
    let figure = args.get_usize("figure").map_err(anyhow::Error::msg)?;
    let epochs = args.get_usize("epochs").map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(args.get_or("out", "runs"));
    // figures 2 and 7 need no PJRT runtime
    match (table, figure) {
        (None, Some(2)) => return reproduce::figure2(&out.join("figures")),
        (None, Some(7)) => return reproduce::figure7(&out.join("figures")),
        _ => {}
    }
    let rt = Runtime::new(artifacts)?;
    reproduce::dispatch(&rt, table, figure, epochs, &out)
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let out = PathBuf::from(args.get_or("out", "data"));
    let stats = autogmap::coordinator::dataset::generate_all(&out)?;
    for (name, dim, nnz) in stats {
        println!("{}: {dim}x{dim}, nnz {nnz} -> {}", name, out.join(format!("{name}.mtx")).display());
    }
    Ok(())
}

fn cmd_visualize(args: &Args) -> anyhow::Result<()> {
    let ds = dataset_from_args(args)?;
    let m = autogmap::coordinator::dataset::load_matrix(&ds)?;
    let r = autogmap::reorder::reorder(&m, Reordering::CuthillMckee);
    println!(
        "{}: {}x{}, nnz {}, sparsity {:.4}, bandwidth {} -> {} (CM)",
        ds.label(),
        m.rows,
        m.cols,
        m.nnz(),
        m.sparsity(),
        r.bandwidth_before,
        r.bandwidth_after
    );
    println!("{}", autogmap::viz::ascii_spy(&r.matrix, 44));
    let out = PathBuf::from(args.get_or("out", "figures"));
    std::fs::create_dir_all(&out)?;
    let g = autogmap::graph::GridSummary::new(&r.matrix, if m.rows > 100 { 32 } else { 2 });
    let file = out.join(format!("{}.svg", ds.label()));
    std::fs::write(&file, autogmap::viz::svg_scheme(&r.matrix, &g, None, &ds.label()))?;
    println!("wrote {}", file.display());
    Ok(())
}

fn cmd_info(artifacts: &str) -> anyhow::Result<()> {
    println!("{}", autogmap::runtime::cpu_client_smoke()?);
    let rt = Runtime::new(artifacts)?;
    match rt.manifest() {
        Ok(m) => {
            println!("manifest fingerprint: {}", m.fingerprint);
            println!("controller configs:");
            for (name, c) in &m.configs {
                println!(
                    "  {name:<18} N={:<3} T={:<3} H={:<3} F={:<2} B={:<2} bilstm={} params={}",
                    c.n,
                    c.steps,
                    c.hidden,
                    c.fill_classes,
                    c.batch,
                    c.bilstm,
                    c.total_param_elements()
                );
            }
            println!("mvm geometries:");
            for (name, v) in &m.mvm {
                println!("  {name:<18} K={} NB={} NR={}", v.k, v.nb, v.nr);
            }
        }
        Err(e) => println!("no artifacts manifest ({e}); run `make artifacts`"),
    }
    Ok(())
}
