//! Pure-Rust mirror of the L2 controller forward pass.
//!
//! Serves three purposes:
//! 1. cross-validation: integration tests teacher-force the HLO rollout's
//!    sampled actions through this mirror and assert the log-probs agree
//!    to float tolerance (catching ABI drift between aot.py and the Rust
//!    parameter layout);
//! 2. a no-artifacts fallback (`--engine rust`) so every CLI command works
//!    before `make artifacts`;
//! 3. documentation-by-construction of the exact controller math
//!    (gate packing (f,i,g,o), Algo. 1 double-step, fill masking).
//!
//! Mirrors `python/compile/model.py` exactly; gradient support is *not*
//! mirrored (training always goes through the AOT train_step artifact).

use crate::runtime::manifest::ControllerEntry;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

/// Controller parameters as named row-major f32 tensors.
pub type Params = BTreeMap<String, Vec<f32>>;

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// One fused LSTM step: returns (h, c). `xh` = [x, h_prev] concatenated,
/// `w` is [(I+H), 4H] row-major, gate packing (f, i, g, o).
fn lstm_step(xh: &[f32], c_prev: &[f32], w: &[f32], b: &[f32], hidden: usize) -> (Vec<f32>, Vec<f32>) {
    let in_dim = xh.len();
    let out_dim = 4 * hidden;
    debug_assert_eq!(w.len(), in_dim * out_dim);
    let mut z = b.to_vec();
    for (i, &xi) in xh.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for (zj, wj) in z.iter_mut().zip(row.iter()) {
            *zj += xi * wj;
        }
    }
    let mut h = vec![0.0; hidden];
    let mut c = vec![0.0; hidden];
    for j in 0..hidden {
        let f = sigmoid(z[j]);
        let i = sigmoid(z[hidden + j]);
        let g = z[2 * hidden + j].tanh();
        let o = sigmoid(z[3 * hidden + j]);
        c[j] = f * c_prev[j] + i * g;
        h[j] = o * c[j].tanh();
    }
    (h, c)
}

fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&l| (l - max).exp()).sum::<f32>().ln() + max;
    logits.iter().map(|&l| l - lse).collect()
}

/// Per-step FC head: logits = inp @ w_t + b_t, where `w_t` is
/// [head_in, classes] row-major.
fn head(inp: &[f32], w_t: &[f32], b_t: &[f32], classes: usize) -> Vec<f32> {
    let mut out = b_t.to_vec();
    for (i, &xi) in inp.iter().enumerate() {
        for j in 0..classes {
            out[j] += xi * w_t[i * classes + j];
        }
    }
    out
}

/// Action selection policy for [`forward`].
pub enum Select<'a> {
    /// Multinomial sampling with this RNG.
    Sample(&'a mut Pcg64),
    /// Deterministic argmax.
    Greedy,
    /// Teacher-forced: score these given actions (d, f per step).
    Teacher { d: &'a [i32], f: &'a [i32] },
}

/// One-episode rollout result.
#[derive(Debug, Clone)]
pub struct Episode {
    pub d_actions: Vec<i32>,
    pub f_actions: Vec<i32>,
    pub logp: f32,
    pub entropy: f32,
}

/// Run the controller for one episode (batch dim of 1 — the Rust mirror is
/// for validation/fallback, not throughput).
pub fn forward(entry: &ControllerEntry, params: &Params, mut select: Select) -> Episode {
    let hidden = entry.hidden;
    let t_steps = entry.steps;
    let fill = entry.fill_classes;
    let head_in = if entry.bilstm { 2 * hidden } else { hidden };

    let get = |name: &str| -> &[f32] {
        params
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name}"))
    };
    let lstm_w = get("lstm_w");
    let lstm_b = get("lstm_b");

    // BiLSTM auxiliary backward pass over learned embeddings.
    let hb: Vec<Vec<f32>> = if entry.bilstm {
        let emb = get("bwd_emb");
        let bwd_w = get("bwd_w");
        let bwd_b = get("bwd_b");
        let mut h = vec![0.0; hidden];
        let mut c = vec![0.0; hidden];
        let mut rev = Vec::with_capacity(t_steps);
        for t in (0..t_steps).rev() {
            let x = &emb[t * hidden..(t + 1) * hidden];
            let mut xh = x.to_vec();
            xh.extend_from_slice(&h);
            let (h2, c2) = lstm_step(&xh, &c, bwd_w, bwd_b, hidden);
            h = h2;
            c = c2;
            rev.push(h.clone());
        }
        rev.reverse();
        rev
    } else {
        Vec::new()
    };

    let mut x = get("x0").to_vec();
    let mut h = vec![0.0f32; hidden];
    let mut c = vec![0.0f32; hidden];
    let mut logp = 0.0f32;
    let mut entropy = 0.0f32;
    let mut d_actions = Vec::with_capacity(t_steps);
    let mut f_actions = Vec::with_capacity(t_steps);

    let fc_d_w = get("fc_d_w");
    let fc_d_b = get("fc_d_b");

    for t in 0..t_steps {
        // --- diagonal decision
        let mut xh = x.clone();
        xh.extend_from_slice(&h);
        let (h1, c1) = lstm_step(&xh, &c, lstm_w, lstm_b, hidden);
        let head_inp: Vec<f32> = if entry.bilstm {
            h1.iter().chain(hb[t].iter()).cloned().collect()
        } else {
            h1.clone()
        };
        let logits_d = head(
            &head_inp,
            &fc_d_w[t * head_in * 2..(t + 1) * head_in * 2],
            &fc_d_b[t * 2..(t + 1) * 2],
            2,
        );
        let lsm_d = log_softmax(&logits_d);
        let d = match &mut select {
            Select::Sample(rng) => {
                let w: Vec<f64> = lsm_d.iter().map(|&l| (l as f64).exp()).collect();
                rng.multinomial(&w) as i32
            }
            Select::Greedy => argmax(&lsm_d),
            Select::Teacher { d, .. } => d[t],
        };
        logp += lsm_d[d as usize];
        entropy -= lsm_d.iter().map(|&l| l.exp() * l).sum::<f32>();
        d_actions.push(d);

        if fill > 0 {
            // --- fill decision (always computed, masked by d == 0)
            let fc_f_w = get("fc_f_w");
            let fc_f_b = get("fc_f_b");
            let mut xh2 = h1.clone();
            xh2.extend_from_slice(&h1);
            let (h2, c2) = lstm_step(&xh2, &c1, lstm_w, lstm_b, hidden);
            let head_inp2: Vec<f32> = if entry.bilstm {
                h2.iter().chain(hb[t].iter()).cloned().collect()
            } else {
                h2.clone()
            };
            let logits_f = head(
                &head_inp2,
                &fc_f_w[t * head_in * fill..(t + 1) * head_in * fill],
                &fc_f_b[t * fill..(t + 1) * fill],
                fill,
            );
            let lsm_f = log_softmax(&logits_f);
            let f = match &mut select {
                Select::Sample(rng) => {
                    let w: Vec<f64> = lsm_f.iter().map(|&l| (l as f64).exp()).collect();
                    rng.multinomial(&w) as i32
                }
                Select::Greedy => argmax(&lsm_f),
                Select::Teacher { f, .. } => f[t],
            };
            f_actions.push(f);
            if d == 0 {
                logp += lsm_f[f as usize];
                entropy -= lsm_f.iter().map(|&l| l.exp() * l).sum::<f32>();
                h = h2;
                c = c2;
            } else {
                h = h1;
                c = c1;
            }
        } else {
            f_actions.push(0);
            h = h1;
            c = c1;
        }
        x = h.clone();
    }

    Episode {
        d_actions,
        f_actions,
        logp,
        entropy,
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::params::init_params;
    use crate::runtime::manifest::ParamSpec;

    fn entry(fill: usize, bilstm: bool) -> ControllerEntry {
        let hidden = 6;
        let n = 5;
        let t = n - 1;
        let head_in = if bilstm { 2 * hidden } else { hidden };
        let mut params = vec![
            ParamSpec { name: "x0".into(), shape: vec![hidden] },
            ParamSpec { name: "lstm_w".into(), shape: vec![2 * hidden, 4 * hidden] },
            ParamSpec { name: "lstm_b".into(), shape: vec![4 * hidden] },
        ];
        if bilstm {
            params.push(ParamSpec { name: "bwd_emb".into(), shape: vec![t, hidden] });
            params.push(ParamSpec { name: "bwd_w".into(), shape: vec![2 * hidden, 4 * hidden] });
            params.push(ParamSpec { name: "bwd_b".into(), shape: vec![4 * hidden] });
        }
        params.push(ParamSpec { name: "fc_d_w".into(), shape: vec![t, head_in, 2] });
        params.push(ParamSpec { name: "fc_d_b".into(), shape: vec![t, 2] });
        if fill > 0 {
            params.push(ParamSpec { name: "fc_f_w".into(), shape: vec![t, head_in, fill] });
            params.push(ParamSpec { name: "fc_f_b".into(), shape: vec![t, fill] });
        }
        ControllerEntry {
            name: "test".into(),
            n,
            hidden,
            fill_classes: fill,
            batch: 1,
            bilstm,
            steps: t,
            params,
            artifacts: Default::default(),
        }
    }

    #[test]
    fn sample_emits_valid_actions() {
        for (fill, bilstm) in [(0, false), (2, false), (4, false), (2, true)] {
            let e = entry(fill, bilstm);
            let params = init_params(&e, 42);
            let mut rng = Pcg64::seed_from_u64(1);
            let ep = forward(&e, &params, Select::Sample(&mut rng));
            assert_eq!(ep.d_actions.len(), e.steps);
            assert!(ep.d_actions.iter().all(|&d| d == 0 || d == 1));
            if fill > 0 {
                assert!(ep.f_actions.iter().all(|&f| (f as usize) < fill));
            }
            assert!(ep.logp < 0.0);
            assert!(ep.entropy > 0.0);
        }
    }

    #[test]
    fn teacher_forcing_reproduces_sampled_logp() {
        let e = entry(4, false);
        let params = init_params(&e, 7);
        let mut rng = Pcg64::seed_from_u64(2);
        let ep = forward(&e, &params, Select::Sample(&mut rng));
        let scored = forward(
            &e,
            &params,
            Select::Teacher {
                d: &ep.d_actions,
                f: &ep.f_actions,
            },
        );
        assert!((scored.logp - ep.logp).abs() < 1e-5);
        assert_eq!(scored.d_actions, ep.d_actions);
    }

    #[test]
    fn greedy_is_deterministic() {
        let e = entry(2, true);
        let params = init_params(&e, 9);
        let a = forward(&e, &params, Select::Greedy);
        let b = forward(&e, &params, Select::Greedy);
        assert_eq!(a.d_actions, b.d_actions);
        assert_eq!(a.f_actions, b.f_actions);
    }

    #[test]
    fn fill_mask_excludes_fill_logp_when_all_extend() {
        // teacher-force all-extend: fill actions must not affect logp.
        let e = entry(4, false);
        let params = init_params(&e, 11);
        let d = vec![1; e.steps];
        let f0 = vec![0; e.steps];
        let f3 = vec![3; e.steps];
        let a = forward(&e, &params, Select::Teacher { d: &d, f: &f0 });
        let b = forward(&e, &params, Select::Teacher { d: &d, f: &f3 });
        assert!((a.logp - b.logp).abs() < 1e-6);
    }
}
