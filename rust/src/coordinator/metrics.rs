//! Metrics logging: CSV per-epoch history + JSON run summary, written under
//! `runs/<experiment>/`. The CSV columns feed the training-curve figures
//! (Figs. 9/11/13) and EXPERIMENTS.md.

use crate::agent::{BestSolution, EpochStats};
use crate::util::json::{obj, Json};
use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

pub const CSV_HEADER: &str =
    "epoch,mean_reward,max_reward,mean_coverage,mean_area,frac_complete,baseline,loss,mean_logp";

/// Append-oriented CSV logger.
pub struct MetricsLog {
    file: std::io::BufWriter<std::fs::File>,
    pub path: PathBuf,
    pub rows: usize,
}

impl MetricsLog {
    pub fn create(dir: &Path) -> Result<MetricsLog> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating run dir {}", dir.display()))?;
        let path = dir.join("metrics.csv");
        let mut file = std::io::BufWriter::new(
            std::fs::File::create(&path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        writeln!(file, "{CSV_HEADER}")?;
        Ok(MetricsLog {
            file,
            path,
            rows: 0,
        })
    }

    pub fn log(&mut self, s: &EpochStats) -> Result<()> {
        writeln!(
            self.file,
            "{},{:.6},{:.6},{:.6},{:.6},{:.4},{:.6},{:.6},{:.6}",
            s.epoch,
            s.mean_reward,
            s.max_reward,
            s.mean_coverage,
            s.mean_area,
            s.frac_complete,
            s.baseline,
            s.loss,
            s.mean_logp
        )?;
        self.rows += 1;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush().context("flushing metrics csv")
    }
}

/// Parse a metrics.csv back into per-column series (figure rendering).
pub fn read_csv(path: &Path) -> Result<Vec<(String, Vec<f64>)>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty metrics csv")?;
    let names: Vec<String> = header.split(',').map(|s| s.to_string()).collect();
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); names.len()];
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        anyhow::ensure!(
            fields.len() == names.len(),
            "metrics.csv line {}: {} fields, expected {}",
            lineno + 2,
            fields.len(),
            names.len()
        );
        for (c, f) in fields.iter().enumerate() {
            cols[c].push(f.parse().with_context(|| {
                format!("metrics.csv line {}: bad number {f:?}", lineno + 2)
            })?);
        }
    }
    Ok(names.into_iter().zip(cols).collect())
}

/// Final run summary (JSON): best solution + last-epoch stats.
pub fn write_summary(
    dir: &Path,
    experiment: &str,
    best: Option<&BestSolution>,
    last: Option<&EpochStats>,
    wall_seconds: f64,
) -> Result<PathBuf> {
    let best_json = match best {
        None => Json::Null,
        Some(b) => obj(vec![
            (
                "diag_blocks",
                Json::Arr(
                    b.scheme
                        .diag_len
                        .iter()
                        .map(|&l| Json::Num(l as f64))
                        .collect(),
                ),
            ),
            (
                "fill_blocks",
                Json::Arr(
                    b.scheme
                        .fill_len
                        .iter()
                        .map(|&l| Json::Num(l as f64))
                        .collect(),
                ),
            ),
            ("coverage_ratio", Json::Num(b.eval.coverage_ratio)),
            ("area_ratio", Json::Num(b.eval.area_ratio)),
            ("sparsity", Json::Num(b.eval.sparsity)),
            ("found_at_epoch", Json::Num(b.epoch as f64)),
        ]),
    };
    let last_json = match last {
        None => Json::Null,
        Some(s) => obj(vec![
            ("epoch", Json::Num(s.epoch as f64)),
            ("mean_reward", Json::Num(s.mean_reward)),
            ("mean_coverage", Json::Num(s.mean_coverage)),
            ("mean_area", Json::Num(s.mean_area)),
            ("frac_complete", Json::Num(s.frac_complete)),
        ]),
    };
    let doc = obj(vec![
        ("experiment", Json::Str(experiment.to_string())),
        ("best", best_json),
        ("last", last_json),
        ("wall_seconds", Json::Num(wall_seconds)),
    ]);
    let path = dir.join("summary.json");
    std::fs::write(&path, doc.to_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheme::Scheme;

    fn stats(epoch: usize) -> EpochStats {
        EpochStats {
            epoch,
            mean_reward: 0.8,
            max_reward: 0.9,
            mean_coverage: 0.95,
            mean_area: 0.4,
            frac_complete: 0.5,
            baseline: 0.79,
            loss: -0.1,
            mean_logp: -3.5,
        }
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("autogmap_metrics_test");
        let mut log = MetricsLog::create(&dir).unwrap();
        for e in 0..5 {
            log.log(&stats(e)).unwrap();
        }
        log.flush().unwrap();
        let cols = read_csv(&log.path).unwrap();
        assert_eq!(cols.len(), 9);
        assert_eq!(cols[0].0, "epoch");
        assert_eq!(cols[0].1, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        assert_eq!(cols[3].1[0], 0.95);
    }

    #[test]
    fn summary_written() {
        let dir = std::env::temp_dir().join("autogmap_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        let best = BestSolution {
            scheme: Scheme {
                diag_len: vec![4, 7],
                fill_len: vec![2],
            },
            eval: crate::scheme::evaluate(
                &Scheme { diag_len: vec![2], fill_len: vec![] },
                &crate::graph::GridSummary::new(&crate::graph::synth::qm7_like(1), 11),
                crate::scheme::RewardWeights::new(0.8),
            ),
            epoch: 12,
        };
        let p = write_summary(&dir, "exp", Some(&best), Some(&stats(99)), 1.5).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("experiment").as_str(), Some("exp"));
        assert_eq!(doc.get("best").get("found_at_epoch").as_usize(), Some(12));
        assert_eq!(doc.get("last").get("epoch").as_usize(), Some(99));
    }

    #[test]
    fn read_csv_rejects_corrupt() {
        let dir = std::env::temp_dir().join("autogmap_metrics_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "a,b\n1\n").unwrap();
        assert!(read_csv(&p).is_err());
        std::fs::write(&p, "a,b\n1,x\n").unwrap();
        assert!(read_csv(&p).is_err());
    }
}
