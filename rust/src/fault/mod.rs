//! Device-fault injection, ABFT output verification, and self-healing
//! repair for the serving stack.
//!
//! The paper's premise is that memristive crossbars are an *imperfect*
//! substrate — limited tile sizes, quantization, device variation — yet
//! the serving tiers below this module assume the programmed arena is
//! flawless. GraphR (PAPERS.md) treats ReRAM reliability as a first-class
//! design input; this subsystem makes it one here, as a full
//! **inject → detect → quarantine → repair** lifecycle over the existing
//! deployment machinery:
//!
//! 1. **Inject** ([`FaultHarness::inject`]) — a deterministic, seedable
//!    device-fault model applied at the fleet/bank level. A [`FaultSpec`]
//!    names a bank, a [`FaultKind`], and a seed; the harness clones the
//!    current program image and corrupts *exactly the programs mapped to
//!    the afflicted bank* (per the fleet's tile→bank assignment — a
//!    deduplicated program serving tiles on several banks has a blast
//!    radius covering all of them). Kinds: stuck-at-zero and stuck-at-one
//!    cells at a per-cell rate, per-bank conductance drift (a
//!    multiplicative Gaussian walk, one factor per "wear" tick), and
//!    whole-bank outage (every mapped cell reads zero). Injection is
//!    *silent*: it swaps in a new generation-numbered [`FaultEpoch`]
//!    carrying the corrupted plan but does not tell the detectors.
//! 2. **Detect** — two independent detectors, both built on state
//!    computed at arm time from the healthy image:
//!    - *ABFT checksum verification* (every served MVM): per-column
//!      checksums `cs_j = Σ_i A_ij` folded once at arm time; a served
//!      output must satisfy `Σ_r y_r ≈ Σ_j cs_j·x_j` within a
//!      scale-relative tolerance ([`FaultOptions::tol_scale`]). One extra
//!      dot product per request — amortized across the multi-RHS batch
//!      path. A corrupted cell that the request actually exercises
//!      perturbs the identity by the full fault magnitude, orders of
//!      magnitude above float-summation noise, so the false-negative
//!      window is the measure-zero set of inputs that cancel the fault
//!      exactly (e.g. `x = 0`, where the corrupted answer is still
//!      correct).
//!    - *Scrub probe* ([`FaultHarness::scrub`], every
//!      [`FaultOptions::scrub_every`] served requests): a fixed
//!      pseudorandom known vector pushed through each bank's tiles and
//!      compared bit-exactly against the healthy per-bank reference —
//!      proactive detection for corruption that request traffic has not
//!      exercised.
//! 3. **Quarantine** — on any detection the harness diffs the corrupted
//!    arena against the healthy image (bit-exact, per program), marks
//!    every row of every tile referencing a corrupted program, and swaps
//!    in a degraded epoch. Quarantined rows are served by the *digital
//!    reference* (the host-CSR spill-path fallback reconstructed at arm
//!    time), so answers stay **bit-identical to the host oracle while
//!    degraded**; unquarantined rows still come off the (healthy part of
//!    the) arena. Responses served under a degraded epoch carry
//!    `"degraded": true` on both transports.
//! 4. **Repair** ([`FaultHarness::repair`]) — re-assign the healthy
//!    plan's tiles over the surviving banks
//!    ([`crate::engine::Fleet::assign_excluding`] — failed banks stay
//!    retired), recompute the per-bank probe references, and atomically
//!    swap the healthy program image back in (an `Arc` swap,
//!    generation-numbered like the net tier's bundle hot-swap; in-flight
//!    batches finish on the epoch they started with). The net tier
//!    exposes this as `{"admin":{"repair":{"id":...}}}`, so repair runs
//!    asynchronously on one connection while others keep serving
//!    degraded.
//!
//! Health counters surface in [`crate::engine::batch::FaultHealth`]
//! (inside every [`crate::engine::ServeStats`] via
//! [`crate::api::Deployment::stats`]) and on the wire in
//! `{"admin":"stats"}`. The `fault-bench` chaos harness ([`bench`])
//! injects mid-stream under concurrent TCP clients, oracle-checks every
//! response, and ledgers detection latency, repair latency, and
//! degraded-mode throughput into `BENCH_fault.json`.
//!
//! The zero-fault contract: an armed harness that never sees an injection
//! serves **bit-identically** to the unarmed path (same executor, same
//! buffers, same numbers) — verification only reads outputs, and the
//! quarantine/fallback machinery only engages after a detection.

pub mod bench;
mod harness;

pub use bench::{run_fault_bench, FaultBenchOptions};
pub use harness::{FaultEpoch, FaultHarness, InjectReport};

use crate::api::error::{Error, Result};

/// Harness configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultOptions {
    /// run a scrub probe every N served requests (0 disables periodic
    /// scrubbing; [`FaultHarness::scrub`] can still be called directly)
    pub scrub_every: u64,
    /// scale-relative checksum tolerance: a verification trips when
    /// `|Σy − Σcs·x| > tol_scale · (Σ|cs·x| + Σ|y| + 1)`. The default
    /// (1e-9) sits ~2 orders above worst-case f64 summation noise at this
    /// repo's matrix sizes and ~9 below any single-cell fault magnitude.
    pub tol_scale: f64,
}

impl Default for FaultOptions {
    fn default() -> FaultOptions {
        FaultOptions {
            scrub_every: 256,
            tol_scale: 1e-9,
        }
    }
}

/// One seedable device-fault mode (see [`crate::crossbar::program`] for
/// the array-level cousins these mirror at the serving layer).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// each cell sticks to zero conductance with probability `rate`
    StuckZero { rate: f64 },
    /// each cell sticks to the program's max-abs level with probability
    /// `rate` (unprogrammed cells can stick too — a shorted device)
    StuckOne { rate: f64 },
    /// multiplicative conductance drift: every programmed cell is scaled
    /// by `Π (1 + sigma·ξ)` over `ticks` wear steps, `ξ ~ N(0,1)`
    Drift { sigma: f64, ticks: u32 },
    /// whole-bank outage: every mapped cell reads zero
    Outage,
}

impl FaultKind {
    /// Parse the wire/CLI form: a kind label plus one magnitude knob
    /// (`rate` for stuck-at kinds, drift sigma for `drift`, ignored for
    /// `outage`).
    pub fn parse(kind: &str, rate: f64) -> Result<FaultKind> {
        if !(0.0..=1.0).contains(&rate) && matches!(kind, "stuck0" | "stuck1") {
            return Err(Error::Validate(format!(
                "stuck-at rate must be in [0, 1], got {rate}"
            )));
        }
        Ok(match kind {
            "stuck0" | "stuck-zero" => FaultKind::StuckZero { rate },
            "stuck1" | "stuck-one" => FaultKind::StuckOne { rate },
            "drift" => FaultKind::Drift { sigma: rate, ticks: 4 },
            "outage" => FaultKind::Outage,
            other => {
                return Err(Error::Validate(format!(
                    "unknown fault kind {other:?} (stuck0|stuck1|drift|outage)"
                )))
            }
        })
    }

    /// Stable ledger/wire label.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::StuckZero { .. } => "stuck0",
            FaultKind::StuckOne { .. } => "stuck1",
            FaultKind::Drift { .. } => "drift",
            FaultKind::Outage => "outage",
        }
    }
}

/// One injection order: which bank, which failure mode, which seed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// afflicted bank (index into the fleet's assignment)
    pub bank: usize,
    pub kind: FaultKind,
    /// fault-model seed — identical specs corrupt identical cells
    pub seed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_kinds_parse_and_label() {
        assert_eq!(
            FaultKind::parse("stuck0", 0.25).unwrap(),
            FaultKind::StuckZero { rate: 0.25 }
        );
        assert_eq!(
            FaultKind::parse("stuck1", 0.1).unwrap().label(),
            "stuck1"
        );
        assert_eq!(
            FaultKind::parse("drift", 0.05).unwrap(),
            FaultKind::Drift { sigma: 0.05, ticks: 4 }
        );
        assert_eq!(FaultKind::parse("outage", 0.0).unwrap(), FaultKind::Outage);
        assert!(FaultKind::parse("melt", 0.5).is_err());
        let err = FaultKind::parse("stuck0", 1.5).unwrap_err();
        assert_eq!(err.kind(), "validate");
    }

    #[test]
    fn default_options_are_sane() {
        let o = FaultOptions::default();
        assert_eq!(o.scrub_every, 256);
        assert!(o.tol_scale > 0.0 && o.tol_scale < 1e-6);
    }
}
