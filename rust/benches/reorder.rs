//! Bench: Cuthill-McKee / RCM reordering throughput (the pre-processing
//! stage of every experiment; paper §VI "the matrices are reordered … as
//! the pre-processing").

use autogmap::graph::synth;
use autogmap::reorder::{cuthill_mckee, reorder, reverse_cuthill_mckee, Reordering};
use autogmap::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();
    let qm7 = synth::qm7_like(5828);
    let qh882 = synth::qh882_like(882);
    let qh1484 = synth::qh1484_like(1484);
    let pl = synth::power_law(2000, 3, 1);

    b.bench("cm/qm7_22", || cuthill_mckee(&qm7));
    b.bench("cm/qh882", || cuthill_mckee(&qh882));
    b.bench("cm/qh1484", || cuthill_mckee(&qh1484));
    b.bench("cm/power_law_2000", || cuthill_mckee(&pl));
    b.bench("rcm/qh882", || reverse_cuthill_mckee(&qh882));
    b.bench("reorder_full/qh1484 (perm+permute+bw)", || {
        reorder(&qh1484, Reordering::CuthillMckee)
    });

    // report achieved bandwidth so the bench doubles as a quality check
    for (name, m) in [("qm7", &qm7), ("qh882", &qh882), ("qh1484", &qh1484)] {
        let r = reorder(m, Reordering::CuthillMckee);
        println!(
            "quality {name}: bandwidth {} -> {}, profile {}",
            r.bandwidth_before,
            r.bandwidth_after,
            r.matrix.profile()
        );
    }
}
